"""Evaluation-pipeline throughput: the paper's ``compare_techniques``
protocol (hour-loop reference vs one-compile batched engine), GT-DRL
best-response round cost (full-width masked vmap vs gathered half dispatch),
and month-scale episodes.

Rows (name, us_per_call, derived):
  engine/compare_loop_<t>     us per 5-env suite evaluation, loop reference
  engine/compare_batched_<t>  us per 5-env suite evaluation; speedup derived
  engine/gtdrl_round_masked   us per game round, full-width masked dispatch
  engine/gtdrl_round_half     us per game round, I/2 gathered dispatch
  engine/month_day_<t>        us per simulated day inside run_month
  engine/day_scan_fd_cost     us per compiled day, plain cost objective
  engine/day_scan_fd_cost_sla us per compiled day with the latency/SLA terms
                              (overhead vs plain cost derived)
  engine/day_scan_routed      us per compiled day over the (S, I, D) routing
                              tensor (overhead vs the unrouted SLA day
                              derived — the cost of the per-source axis)
  engine/day_scan_tap_overhead us per compiled day with the engine/hour tap
                              streaming (overhead vs the silent taps-off
                              artifact derived — the price of live telemetry)
  engine/day_batched_sharded  us per batched fleet evaluation through the
                              shard_map-sharded env axis (overhead vs the
                              plain vmapped engine derived; on one device
                              the two run the identical program)
  engine/sweep_grid           us per severity-sweep grid (ExperimentSpec
                              ``sweep``: stacked grid envs, one batched
                              compile per technique)
  engine/day_scan_faulted     us per compiled day through the plan/execute
                              split (realized FaultTrace + failover
                              re-projection each hour; overhead vs the
                              unfaulted day derived — the price of
                              executing on the realized env)
  engine/sweep_resume         us per journaled severity-sweep grid
                              (chunked execution, one checkpoint per
                              chunk; overhead vs the one-compile in-memory
                              sweep derived — the price of crash safety)
  engine/build_env_llm        us per token-grounded env build (the llm
                              capability layer: roofline derivation over
                              the model zoo x accelerator mix; overhead vs
                              the aibench constant tables derived)
  engine/day_scan_llm         us per compiled day on the derived llm env
                              (I = model families instead of the paper's
                              task types; overhead vs the aibench day
                              derived — the engines are workload-agnostic,
                              so this tracks the I-axis cost alone)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import scenarios as S
from repro.core import gt_drl
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.core.game import GameContext
from repro.core.nash import NashConfig
from repro.dcsim import env as E

from .common import HOURS, QUICK, Timer, emit

CFGS = {"fd": FDConfig(iters=60), "nash": NashConfig(sweeps=3, inner_steps=20)}

# paper-default PPO inner loop (the FLOP-dominated regime the half dispatch
# targets; tiny configs are overhead-bound and hide the win), few rounds
GTDRL_BENCH = gt_drl.GTDRLConfig(rounds=2, pretrain_iters=2)


def run(rows):
    env = E.build_env(4, seed=0)
    suite = S.build_suite("baseline", env)  # the paper's 5 resampled-arrival days
    envs = [e for _, e in suite]
    n = len(envs)
    techniques = ("fd",) if QUICK else ("fd", "nash")

    # -- compare_techniques: loop reference vs one-compile batched engine ----
    for t in techniques:
        kw = dict(objective="carbon", hours=HOURS, seed0=0,
                  cfg_overrides={t: CFGS[t]})
        SCH.compare_techniques(envs, (t,), engine="loop", **kw)   # warm jits
        with Timer() as tm:
            res_loop = SCH.compare_techniques(envs, (t,), engine="loop", **kw)
        loop_s = tm.seconds
        emit(rows, f"engine/compare_loop_{t}", loop_s,
             f"envs={n};mean={res_loop[t]['mean']:.0f}")

        SCH.compare_techniques(envs, (t,), engine="batched", **kw)  # warm
        with Timer() as tm:
            res_b = SCH.compare_techniques(envs, (t,), engine="batched", **kw)
        emit(rows, f"engine/compare_batched_{t}", tm.seconds,
             f"envs={n};speedup_vs_loop={loop_s / max(tm.seconds, 1e-9):.0f}x;"
             f"mean={res_b[t]['mean']:.0f}")

    # -- GT-DRL round cost: masked full-width vmap vs gathered half dispatch -
    key = jax.random.PRNGKey(0)
    ctx = GameContext(env=env, tau=jnp.int32(12), objective="carbon")
    peak = jnp.zeros((E.num_dcs(env),))
    round_times = {}
    for impl in ("masked", "gather"):
        cfg = dataclasses.replace(GTDRL_BENCH, half_update=impl)
        agents = gt_drl.init_agents(key, env, cfg)
        fn = jax.jit(functools.partial(gt_drl.solve_epoch, cfg=cfg))
        jax.block_until_ready(fn(key, agents, ctx, peak))  # warm
        with Timer() as tm:
            jax.block_until_ready(fn(key, agents, ctx, peak))
        round_times[impl] = tm.seconds / cfg.rounds
    emit(rows, "engine/gtdrl_round_masked", round_times["masked"],
         f"rounds={GTDRL_BENCH.rounds};players={E.num_players(env)}")
    emit(rows, "engine/gtdrl_round_half", round_times["gather"],
         f"speedup_vs_masked={round_times['masked'] / max(round_times['gather'], 1e-9):.1f}x")

    # -- month-scale episodes: second-level scan threading the peak state ----
    days = 3 if QUICK else 7
    month = S.build_month(env, days=days, seed=0)
    menvs = [e for _, e in month]
    mkw = dict(objective="carbon", hours=HOURS, seed=0, cfg_override=CFGS["fd"])
    SCH.run_month(menvs, "fd", **mkw)  # warm
    with Timer() as tm:
        res_m = SCH.run_month(menvs, "fd", **mkw)
    emit(rows, "engine/month_day_fd", tm.seconds / days,
         f"days={days};peak_final_kw={res_m['final_peak_w'].max() / 1e3:.0f}")

    # -- SLA-enabled compiled day: the latency/SLA terms must stay cheap -----
    sla_env = S.make("wan_degradation")(S.make("sla_tighten", tighten=0.8)(env))
    day_s = {}
    for obj in ("cost", "cost_sla"):
        kw = dict(objective=obj, hours=HOURS, seed=0, cfg_override=CFGS["fd"])
        SCH.run_day(sla_env, "fd", **kw)  # warm
        with Timer() as tm:
            res_d = SCH.run_day(sla_env, "fd", **kw)
        day_s[obj] = tm.seconds
        emit(rows, f"engine/day_scan_fd_{obj}", tm.seconds,
             f"hours={HOURS};sla_usd={res_d['totals']['sla_miss_cost_usd']:.0f}"
             + (f";overhead_vs_cost={day_s['cost_sla'] / max(day_s['cost'], 1e-9):.2f}x"
                if obj == "cost_sla" else ""))

    # -- tap overhead: the telemetry-streaming day vs the silent artifact ----
    from repro import obs
    from repro.core import experiment as X
    tap_spec = X.ExperimentSpec(technique="fd", objective="cost", hours=HOURS,
                                cfg=CFGS["fd"], taps=())
    X.run(tap_spec, sla_env)  # warm the taps-off artifact
    with Timer() as tm:
        X.run(tap_spec, sla_env)
    off_s = tm.seconds
    tapped = tap_spec.replace(taps=("engine/hour",))
    X.run(tapped, sla_env)  # warm the tapped artifact (separate compile key)
    with obs.capture("engine/hour") as buf, Timer() as tm:
        X.run(tapped, sla_env)
    emit(rows, "engine/day_scan_tap_overhead", tm.seconds,
         f"hours={HOURS};events={len(buf.events)};"
         f"overhead_vs_off={tm.seconds / max(off_s, 1e-9):.2f}x")

    # -- routed day: the (S, I, D) routing tensor's compile/runtime cost -----
    route_env = S.make("origin_shift", toward=(0,), weight=0.8)(sla_env)
    rkw = dict(objective="cost_sla", hours=HOURS, seed=0,
               cfg_override=CFGS["fd"], routed=True)
    SCH.run_day(route_env, "fd", **rkw)  # warm (includes the routed compile)
    with Timer() as tm:
        res_r = SCH.run_day(route_env, "fd", **rkw)
    emit(rows, "engine/day_scan_routed", tm.seconds,
         f"hours={HOURS};sources={E.num_sources(route_env)};"
         f"sla_usd={res_r['totals']['sla_miss_cost_usd']:.0f};"
         f"overhead_vs_unrouted={tm.seconds / max(day_s['cost_sla'], 1e-9):.2f}x")

    # -- spec-driven engines: device-sharded batched day + severity sweep ----
    spec = X.ExperimentSpec(technique="fd", objective="carbon", engine="batched",
                            hours=HOURS, cfg=CFGS["fd"])
    env_b = E.stack_envs(envs)
    X.run(spec, env_b)  # warm (shares the spec-keyed cache with compare above)
    with Timer() as tm:
        X.run(spec, env_b)
    plain_s = tm.seconds
    X.run(spec, env_b, shard=True)  # warm the shard_map compile
    with Timer() as tm:
        res_sh = X.run(spec, env_b, shard=True)
    emit(rows, "engine/day_batched_sharded", tm.seconds,
         f"devices={jax.device_count()};envs={n};"
         f"overhead_vs_vmap={tm.seconds / max(plain_s, 1e-9):.2f}x;"
         f"mean={res_sh['totals']['carbon_kg'].mean():.0f}")

    grid = {"wan_degradation": (1.0, 3.0), "origin_shift": (0.0, 0.7)}
    sweep_spec = X.ExperimentSpec(technique="fd", objective="cost_sla",
                                  engine="batched", routed=True, hours=HOURS,
                                  cfg=CFGS["fd"])
    base = (S.Scenario("sla_tighten", {"tighten": 0.7}),)
    skw = dict(base_env=env, base_scenarios=base)
    X.sweep(sweep_spec, grid, **skw)  # warm
    with Timer() as tm:
        res_g = X.sweep(sweep_spec, grid, **skw)
    sweep_s = tm.seconds
    n_pts = len(res_g["labels"])
    emit(rows, "engine/sweep_grid", sweep_s,
         f"points={n_pts};hours={HOURS};"
         f"us_per_point={sweep_s * 1e6 / n_pts:.0f};"
         f"sla_usd_max={res_g['results']['fd']['totals']['sla_miss_cost_usd'].max():.0f}")

    # -- token-grounded llm workload: capability derivation + compiled day --
    E.build_env(4, seed=0, workload="llm")  # warm (config imports etc.)
    with Timer() as tm:
        for _ in range(3):
            E.build_env(4, seed=0, workload="llm")
    build_llm_s = tm.seconds / 3
    with Timer() as tm:
        for _ in range(3):
            E.build_env(4, seed=0)
    build_aib_s = tm.seconds / 3
    emit(rows, "engine/build_env_llm", build_llm_s,
         f"families={E.build_env(4, seed=0, workload='llm').er.shape[0]};"
         f"overhead_vs_aibench={build_llm_s / max(build_aib_s, 1e-9):.2f}x")

    llm_env = E.build_env(4, seed=0, workload="llm")
    lspec = X.ExperimentSpec(technique="fd", objective="cost", hours=HOURS,
                             cfg=CFGS["fd"], workload="llm")
    X.run(lspec, llm_env)  # warm (separate compile key: workload + I retrace)
    with Timer() as tm:
        res_l = X.run(lspec, llm_env)
    emit(rows, "engine/day_scan_llm", tm.seconds,
         f"hours={HOURS};families={llm_env.er.shape[0]};"
         f"cost={res_l['totals']['cost_usd']:.0f};"
         f"overhead_vs_aibench={tm.seconds / max(day_s['cost'], 1e-9):.2f}x")

    # -- realized faults: the plan/execute split vs the plain compiled day --
    from repro import faults as FL
    day_spec = X.ExperimentSpec(technique="fd", objective="cost",
                                hours=HOURS, cfg=CFGS["fd"])
    trace = FL.compose(FL.dc_crash(sla_env, dc=1, start=HOURS // 3,
                                   duration=HOURS // 2),
                       FL.wan_partition(sla_env, a=0, b=2, extra_ms=300.0))
    X.run(day_spec, sla_env)  # warm the unfaulted artifact
    with Timer() as tm:
        X.run(day_spec, sla_env)
    plain_day_s = tm.seconds
    X.run(day_spec, sla_env, faults=trace)  # warm the faulted artifact
    with Timer() as tm:
        res_f = X.run(day_spec, sla_env, faults=trace)
    emit(rows, "engine/day_scan_faulted", tm.seconds,
         f"hours={HOURS};moved={res_f['totals']['failover_moved']:.0f};"
         f"overhead_vs_plain={tm.seconds / max(plain_day_s, 1e-9):.2f}x")

    # -- resumable sweep: journaled chunk execution vs the in-memory sweep --
    import shutil
    import tempfile
    journal = tempfile.mkdtemp(prefix="bench_sweep_resume_")
    try:
        with Timer() as tm:
            X.sweep(sweep_spec, grid, resume_dir=journal, **skw)
        emit(rows, "engine/sweep_resume", tm.seconds,
             f"points={n_pts};chunks={n_pts};"
             f"overhead_vs_inmem={tm.seconds / max(sweep_s, 1e-9):.2f}x")
    finally:
        shutil.rmtree(journal, ignore_errors=True)
