"""Benchmark harness: one benchmark per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (and tees a summary). Set
REPRO_BENCH_QUICK=1 for a fast smoke pass. ``--json PATH`` additionally
writes the rows as machine-readable JSON (the perf-trajectory workflow:
``make bench-smoke`` commits ``BENCH_engine.json`` so every perf PR records
its loop-vs-scan-vs-batched timings).

    PYTHONPATH=src python -m benchmarks.run [--only carbon,costs,...] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import time

ALL = ("carbon", "scalability", "arrival", "renewables", "costs", "scenarios",
       "engine", "roofline", "micro")


def rows_to_json(rows, which, wall_s: float) -> dict:
    """Parse the CSV rows into the BENCH_*.json payload."""
    from repro import obs

    from .common import HOURS, QUICK, RUNS
    entries = []
    for r in rows[1:]:  # skip the header
        name, us, derived = r.split(",", 2)
        entries.append({"name": name, "us_per_call": float(us),
                        "derived": derived})
    return {
        "meta": {
            "which": list(which),
            "quick": QUICK,
            "hours": HOURS,
            "runs": RUNS,
            "wall_s": round(wall_s, 1),
            "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            # provenance: a perf number without the machine/toolchain that
            # produced it is not comparable across PRs
            **obs.run_info(),
        },
        "rows": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON to PATH")
    args = ap.parse_args()
    which = tuple(args.only.split(",")) if args.only else ALL

    rows = ["name,us_per_call,derived"]
    print(rows[0], flush=True)
    t0 = time.time()

    carbon_res = None
    if "carbon" in which:
        from . import bench_carbon
        carbon_res = bench_carbon.run(rows)
    if "scalability" in which:
        from . import bench_scalability
        bench_scalability.run(rows, carbon_4dc=carbon_res)
    if "arrival" in which:
        from . import bench_arrival
        bench_arrival.run(rows)
    if "renewables" in which:
        from . import bench_renewables
        bench_renewables.run(rows)
    if "costs" in which:
        from . import bench_costs
        bench_costs.run(rows)
    if "scenarios" in which:
        from . import bench_scenarios
        bench_scenarios.run(rows)
    if "engine" in which:
        from . import bench_engine
        bench_engine.run(rows)
    if "roofline" in which:
        from . import bench_roofline
        bench_roofline.run(rows)
    if "micro" in which:
        from . import bench_microbench
        bench_microbench.run(rows)

    wall = time.time() - t0
    print(f"# total benchmark wall time: {wall:.0f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows_to_json(rows, which, wall), f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
