"""Benchmark harness: one benchmark per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (and tees a summary). Set
REPRO_BENCH_QUICK=1 for a fast smoke pass.

    PYTHONPATH=src python -m benchmarks.run [--only carbon,costs,...]
"""
from __future__ import annotations

import argparse
import sys
import time

ALL = ("carbon", "scalability", "arrival", "renewables", "costs", "scenarios",
       "roofline", "micro")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    which = tuple(args.only.split(",")) if args.only else ALL

    rows = ["name,us_per_call,derived"]
    print(rows[0], flush=True)
    t0 = time.time()

    carbon_res = None
    if "carbon" in which:
        from . import bench_carbon
        carbon_res = bench_carbon.run(rows)
    if "scalability" in which:
        from . import bench_scalability
        bench_scalability.run(rows, carbon_4dc=carbon_res)
    if "arrival" in which:
        from . import bench_arrival
        bench_arrival.run(rows)
    if "renewables" in which:
        from . import bench_renewables
        bench_renewables.run(rows)
    if "costs" in which:
        from . import bench_costs
        bench_costs.run(rows)
    if "scenarios" in which:
        from . import bench_scenarios
        bench_scenarios.run(rows)
    if "roofline" in which:
        from . import bench_roofline
        bench_roofline.run(rows)
    if "micro" in which:
        from . import bench_microbench
        bench_microbench.run(rows)

    print(f"# total benchmark wall time: {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
