"""Paper Fig. 9: per-epoch carbon under sinusoidal vs flat arrivals, 8 DCs."""
from __future__ import annotations

import numpy as np

from repro.core.schedulers import compare_techniques

from .common import HOURS, Timer, build_envs, emit

TECHS = ("fd", "nash", "ppo", "gt-drl")  # the paper's highlighted curves


def run(rows) -> dict:
    out = {}
    for pattern in ("sinusoidal", "flat"):
        envs = build_envs(8, runs=2, pattern=pattern)
        with Timer() as t:
            res = compare_techniques(envs, TECHS, "carbon", hours=HOURS)
        for tech in TECHS:
            curve = np.asarray(res[tech]["curve_mean"])
            peak_epoch = int(np.argmax(curve))
            emit(rows, f"arrival_{pattern}/{tech}", t.seconds / len(TECHS),
                 f"day_kg={res[tech]['mean']:.1f};peak_epoch={peak_epoch};"
                 f"peak_kg={curve.max():.1f}")
        out[pattern] = res
    return out
