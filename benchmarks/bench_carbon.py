"""Paper Fig. 7: cloud carbon emissions per technique, 4 DCs, sinusoidal."""
from __future__ import annotations

from repro.core.schedulers import compare_techniques

from .common import HOURS, TECHNIQUES, Timer, build_envs, emit


def run(rows) -> dict:
    envs = build_envs(4)
    with Timer() as t:
        res = compare_techniques(envs, TECHNIQUES, "carbon", hours=HOURS)
    gt = res["gt-drl"]["mean"]
    for tech in TECHNIQUES:
        m, se = res[tech]["mean"], res[tech]["stderr"]
        red = 100.0 * (m - gt) / m if tech != "gt-drl" else 0.0
        emit(rows, f"carbon_4dc/{tech}", t.seconds / len(TECHNIQUES),
             f"day_kg={m:.1f};stderr={se:.1f};gtdrl_reduction_pct={red:.1f}")
    return res
