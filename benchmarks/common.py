"""Shared helpers for the benchmark harness (paper experiment protocol)."""
from __future__ import annotations

import os
from typing import List

from repro import obs
from repro.dcsim import env as E

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

RUNS = 2 if QUICK else int(os.environ.get("REPRO_BENCH_RUNS", "2"))  # paper: 5 runs
HOURS = 6 if QUICK else 24        # paper: 24 one-hour epochs
TECHNIQUES = ("fd", "ga", "nash", "ddpg", "ppo", "gt-drl")


def build_envs(num_dcs: int, runs: int = RUNS, pattern: str = "sinusoidal",
               month: int = 6) -> List[E.EnvParams]:
    """One env per run: same infrastructure, resampled arrival rates
    (the paper's normal resampling with 20% std)."""
    return [E.build_env(num_dcs, seed=r, pattern=pattern, month=month)
            for r in range(runs)]


class Timer(obs.Span):
    """A bench region timer; now an ``obs.Span`` so benchmark timings land
    in the same span stream as the engine telemetry (``obs.all_spans()``)."""

    def __init__(self):
        super().__init__(name="bench")

    @property
    def t0(self):  # legacy alias used by older bench scripts
        return self._t0


def emit(rows: List[str], name: str, seconds: float, derived: str):
    """CSV row: name, microseconds per call, derived metric string."""
    obs.note_bench(name, seconds, derived)
    rows.append(f"{name},{seconds * 1e6:.0f},{derived}")
    print(rows[-1], flush=True)
