"""Paper Fig. 11: cloud operating costs (energy + peak + network), 4/8/16 DCs."""
from __future__ import annotations

import numpy as np

from repro.core.schedulers import compare_techniques

from .common import HOURS, QUICK, TECHNIQUES, Timer, build_envs, emit


def run(rows) -> dict:
    out = {}
    sizes = (4,) if QUICK else (4, 8, 16)
    for nd in sizes:
        envs = build_envs(nd, runs=2)
        with Timer() as t:
            res = compare_techniques(envs, TECHNIQUES, "cost", hours=HOURS)
        gt = res["gt-drl"]["mean"]
        for tech in TECHNIQUES:
            m = res[tech]["mean"]
            red = 100.0 * (m - gt) / m if tech != "gt-drl" else 0.0
            emit(rows, f"cost_{nd}dc/{tech}", t.seconds / len(TECHNIQUES),
                 f"day_usd={m:.0f};gtdrl_reduction_pct={red:.1f}")
        # first-epoch peak-demand spike (paper: first day of billing month)
        curve = np.asarray(res["gt-drl"]["curve_mean"])
        emit(rows, f"cost_{nd}dc/first_epoch_share", 0.0,
             f"share={float(curve[0] / max(curve.sum(), 1e-9)):.3f}")
        out[nd] = res
    return out
