"""Paper Fig. 8: % carbon reduction of GT-DRL vs each technique, 4/8/16 DCs."""
from __future__ import annotations

from repro.core.schedulers import compare_techniques

from .common import HOURS, TECHNIQUES, Timer, build_envs, emit


def run(rows, carbon_4dc=None) -> dict:
    out = {}
    for nd in (4, 8, 16):
        if nd == 4 and carbon_4dc is not None:
            res = carbon_4dc  # reuse Fig. 7's runs
            secs = 0.0
        else:
            envs = build_envs(nd, runs=2)
            with Timer() as t:
                res = compare_techniques(envs, TECHNIQUES, "carbon", hours=HOURS)
            secs = t.seconds
        gt = res["gt-drl"]["mean"]
        for tech in TECHNIQUES:
            if tech == "gt-drl":
                continue
            red = 100.0 * (res[tech]["mean"] - gt) / res[tech]["mean"]
            emit(rows, f"scalability_{nd}dc/{tech}", secs / max(len(TECHNIQUES), 1),
                 f"gtdrl_carbon_reduction_pct={red:.2f}")
        out[nd] = res
    return out
