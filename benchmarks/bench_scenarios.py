"""Scenario engine throughput: us-per-day for the reference Python hour-loop
vs. the compiled lax.scan day vs. the vmapped scenario-suite batch.

Rows (name, us_per_call = us per simulated day, derived):
  scenarios/day_loop_<t>   — seed-style Python loop (jitted per-epoch solver)
  scenarios/day_scan_<t>   — one jitted lax.scan call per day
  scenarios/day_batch_<t>  — run_days_batched over the full stress suite
"""
from __future__ import annotations

from repro import scenarios as S
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.core.nash import NashConfig
from repro.dcsim import env as E

from .common import HOURS, QUICK, Timer, emit

CFGS = {"fd": FDConfig(iters=60), "nash": NashConfig(sweeps=3, inner_steps=20)}


def run(rows):
    env = E.build_env(4, seed=0)
    suite = S.build_suite("stress", env)
    envs = [e for _, e in suite]
    n = len(envs)
    techniques = ("fd",) if QUICK else ("fd", "nash")

    for t in techniques:
        cfg = CFGS[t]
        kw = dict(objective="carbon", seed=0, hours=HOURS, cfg_override=cfg)

        SCH.run_day(env, t, engine="loop", **kw)  # warm the per-epoch jit
        with Timer() as tm:
            res_loop = SCH.run_day(env, t, engine="loop", **kw)
        loop_s = tm.seconds
        emit(rows, f"scenarios/day_loop_{t}", loop_s,
             f"carbon={res_loop['totals']['carbon_kg']:.0f}kg")

        SCH.run_day(env, t, engine="scan", **kw)  # warm the day jit
        with Timer() as tm:
            res_scan = SCH.run_day(env, t, engine="scan", **kw)
        scan_s = tm.seconds
        emit(rows, f"scenarios/day_scan_{t}", scan_s,
             f"speedup_vs_loop={loop_s / max(scan_s, 1e-9):.0f}x")

        bkw = dict(objective="carbon", seeds=[0] * n, hours=HOURS, cfg_override=cfg)
        SCH.run_days_batched(envs, t, **bkw)  # warm the vmapped jit
        with Timer() as tm:
            SCH.run_days_batched(envs, t, **bkw)
        emit(rows, f"scenarios/day_batch_{t}", tm.seconds / n,
             f"days={n};speedup_vs_loop={loop_s / max(tm.seconds / n, 1e-9):.0f}x")
