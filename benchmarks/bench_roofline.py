"""Roofline table (deliverable g): reads the dry-run artifacts and prints
per-(arch × shape) terms + bottleneck + useful-compute ratio."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def run(rows, dryrun_dir: str = "experiments/dryrun") -> dict:
    out = {}
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*__16x16.json")))
    if not files:
        emit(rows, "roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return out
    for f in files:
        r = json.load(open(f))
        tag = f"{r['arch']}/{r['shape']}"
        if r.get("status") == "SKIP":
            emit(rows, f"roofline/{tag}", 0.0, "SKIP:" + r.get("reason", "")[:60])
            continue
        if r.get("status") != "OK":
            emit(rows, f"roofline/{tag}", 0.0, "FAIL")
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom > 0 else 0.0
        emit(rows, f"roofline/{tag}", r.get("compile_s", 0) * 1e6 / 1e6,
             f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
             f"collective_s={r['collective_s']:.4g};bottleneck={r['bottleneck']};"
             f"roofline_frac={frac:.3f};useful={r['useful_ratio']:.3f};"
             f"mem_gb={r['memory_per_device_gb']:.2f}")
        out[tag] = r
    return out
