"""Wall-clock micro-benchmarks of the substrate primitives on this host.

Not a paper figure — these are the us_per_call numbers the harness format
asks for: solver latencies (the paper's "DRL runs in seconds" claim) and
model-step throughputs for the smoke configs.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import gt_drl, nash
from repro.core.game import GameContext
from repro.data.tokens import TokenPipeline
from repro.dcsim import env as E
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, train_step

from .common import emit


def _time(fn, n=5):
    fn()  # compile
    t0 = time.time()
    for _ in range(n):
        r = fn()
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, r)
    return (time.time() - t0) / n


def run(rows) -> dict:
    env = E.build_env(4, seed=0)
    peak = jnp.zeros((4,))
    ctx = GameContext(env=env, tau=jnp.int32(12), objective="carbon")

    # NASH epoch solve latency (paper: math methods get up to 1h; ours: ms)
    nash_fn = jax.jit(functools.partial(nash.solve_epoch, cfg=nash.NashConfig()))
    s = _time(lambda: nash_fn(None, ctx, peak))
    emit(rows, "micro/nash_epoch_solve", s, f"per_epoch_s={s:.3f}")

    # GT-DRL epoch solve latency (paper §6: "runs in a few seconds")
    cfg = gt_drl.GTDRLConfig()
    agents = gt_drl.init_agents(jax.random.PRNGKey(0), env, cfg)
    gt_fn = jax.jit(lambda k, a, c, p: gt_drl.solve_epoch(k, a, c, p, cfg))
    key = jax.random.PRNGKey(1)
    s = _time(lambda: gt_fn(key, agents, ctx, peak), n=3)
    emit(rows, "micro/gtdrl_epoch_solve", s, f"per_epoch_s={s:.3f}")

    # smoke-model train step throughput
    mcfg = get_config("llama3.2-1b").smoke()
    ocfg = AdamWConfig(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), mcfg, ocfg)
    pipe = TokenPipeline(mcfg, seed=0, batch=8, seq=256)
    step = jax.jit(functools.partial(train_step, cfg=mcfg, opt_cfg=ocfg))
    batch = pipe.next()
    state, _ = step(state, batch)  # compile
    t0 = time.time()
    n = 10
    for _ in range(n):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.time() - t0) / n
    toks = 8 * 256 / dt
    emit(rows, "micro/train_step_smoke", dt, f"tokens_per_s={toks:.0f}")
    return {}
