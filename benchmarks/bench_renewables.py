"""Paper Fig. 10: carbon vs renewable availability over 12 months, 8 DCs."""
from __future__ import annotations

import numpy as np

from repro.core.schedulers import compare_techniques

from .common import HOURS, QUICK, Timer, build_envs, emit

TECHS = ("nash", "ppo", "gt-drl")  # Fig. 10(b) fine-scale comparison


def run(rows) -> dict:
    months = (1, 4, 6, 10) if QUICK else tuple(range(1, 13))
    out = {}
    for month in months:
        envs = build_envs(8, runs=1, month=month)
        with Timer() as t:
            res = compare_techniques(envs, TECHS, "carbon", hours=HOURS)
        rp_total = float(np.asarray(envs[0].rp).sum())
        for tech in TECHS:
            emit(rows, f"renewables_m{month:02d}/{tech}", t.seconds / len(TECHS),
                 f"day_kg={res[tech]['mean']:.1f};renewable_wh={rp_total:.3e}")
        out[month] = {"res": res, "rp": rp_total}
    # paper claim: emissions fall as renewables rise (GT-DRL curve)
    rps = np.asarray([out[m]["rp"] for m in months])
    ems = np.asarray([out[m]["res"]["gt-drl"]["mean"] for m in months])
    corr = float(np.corrcoef(rps, ems)[0, 1])
    emit(rows, "renewables_corr/gt-drl", 0.0, f"corr_rp_vs_carbon={corr:.3f}")
    return out
