"""Scenario engine + compiled day engine: registry round-trips, transform
invariants (shapes/dtypes, purity, feasibility under outage/surge), and the
scanned/batched day engines agreeing with the reference Python loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios as S
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.core.nash import NashConfig
from repro.dcsim import env as E
from repro.dcsim import workload

ENV = E.build_env(4, seed=0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_the_advertised_transforms():
    required = {"flash_crowd", "dc_outage", "carbon_spike", "price_surge",
                "renewable_drought", "demand_response", "traffic_pattern",
                "arrival_resample"}
    assert required <= set(S.names())
    assert len(S.names()) >= 7


def test_registry_round_trips_by_name():
    spec = S.Scenario("flash_crowd", {"start": 20, "duration": 2, "magnitude": 2.0})
    direct = S.make(spec.name, **spec.params)(ENV)
    via_spec = spec.apply(ENV)
    for a, b in zip(jax.tree_util.tree_leaves(direct),
                    jax.tree_util.tree_leaves(via_spec)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_registry_unknown_name_raises():
    with pytest.raises(KeyError):
        S.get("no-such-event")
    with pytest.raises(KeyError):
        S.build_suite("no-such-suite", ENV)


def test_compose_applies_left_to_right():
    double = S.make("flash_crowd", start=0, duration=24, magnitude=2.0)
    halve = S.make("flash_crowd", start=0, duration=24, magnitude=0.5)
    out = S.compose(double, halve)(ENV)
    np.testing.assert_allclose(np.asarray(out.car), np.asarray(ENV.car), rtol=1e-6)


# ---------------------------------------------------------------------------
# transform invariants: every registered transform, default params
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", S.names())
def test_transform_preserves_shapes_dtypes_and_is_pure(name):
    t = S.make(name)
    out1, out2 = t(ENV), t(ENV)
    for a, b, c in zip(jax.tree_util.tree_leaves(ENV),
                       jax.tree_util.tree_leaves(out1),
                       jax.tree_util.tree_leaves(out2)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))  # purity
    assert bool(jnp.all(out1.avail >= 0)) and bool(jnp.all(out1.avail <= 1))
    assert bool(jnp.all(out1.car >= 0))


@pytest.mark.parametrize("scenario", [
    S.Scenario("dc_outage", {"dc": 0, "start": 8, "duration": 6}),
    S.Scenario("flash_crowd", {"start": 18, "duration": 4, "magnitude": 3.0}),
    S.Scenario("demand_response", {"dc": 1, "start": 16, "duration": 4, "curtail": 0.6}),
])
def test_project_feasible_under_events(scenario):
    """Eqs. (1)-(2) still hold after outage/surge: AR <= ER·avail, AR >= 0,
    and the split sums to CAR whenever the fleet has headroom."""
    env = scenario.apply(ENV)
    for tau in (2, 10, 18):
        ar = E.project_feasible(env, jnp.full((10, 4), 0.25), tau)
        er_t = E.capacity_at(env, tau)
        assert bool(jnp.all(ar <= er_t * (1 + 1e-5)))
        assert bool(jnp.all(ar >= 0))
        headroom = float(jnp.sum(er_t)) - float(jnp.sum(env.car[:, tau]))
        if headroom > 0:
            np.testing.assert_allclose(np.asarray(jnp.sum(ar, axis=1)),
                                       np.asarray(env.car[:, tau]), rtol=2e-3)


def test_capacity_fractions_respect_outage():
    """The natural starting point puts no mass on an outaged DC."""
    from repro.core.game import GameContext, capacity_fractions
    env = S.make("dc_outage", dc=0, start=8, duration=6)(ENV)
    f_out = capacity_fractions(GameContext(env=env, tau=jnp.int32(10)))
    assert float(jnp.sum(f_out[:, 0])) == 0.0
    np.testing.assert_allclose(np.asarray(jnp.sum(f_out, axis=1)), 1.0, rtol=1e-5)
    f_on = capacity_fractions(GameContext(env=env, tau=jnp.int32(20)))
    assert float(jnp.sum(f_on[:, 0])) > 0.0


def test_run_day_rejects_unknown_engine():
    with pytest.raises(ValueError):
        SCH.run_day(ENV, "fd", engine="Scan")


def test_outage_window_zeroes_the_dc():
    env = S.make("dc_outage", dc=0, start=8, duration=6)(ENV)
    for tau in range(8, 14):
        ar = E.project_feasible(env, jnp.full((10, 4), 0.25), tau)
        assert float(jnp.sum(ar[:, 0])) == 0.0
        assert float(E.grid_power(env, ar, tau)[0]) <= 0.0  # only rp export
    # outside the window the DC is back
    ar = E.project_feasible(env, jnp.full((10, 4), 0.25), 20)
    assert float(jnp.sum(ar[:, 0])) > 0.0


def test_suites_materialize_with_consistent_shapes():
    for suite in S.suite_names():
        rows = S.build_suite(suite, ENV)
        assert len(rows) >= 1
        for _, env in rows:
            assert env.car.shape == ENV.car.shape
    assert len(S.build_suite("stress", ENV)) >= 8


# ---------------------------------------------------------------------------
# workload patterns (scenario traffic families)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", workload.PATTERNS)
def test_arrival_patterns_shape_and_positive(kind):
    base = workload.base_rates(np.asarray(ENV.er).sum(axis=1))
    car = workload.arrival_pattern(kind, base, seed=3)
    assert car.shape == (10, 24)
    assert np.all(car > 0)


def test_bursty_spikes_never_overlap_multiply():
    """Seeded spike windows are disjoint: every spiked hour carries exactly
    one 2–3.3× magnitude over the 0.30 base (overlapping draws used to
    multiply into the cap and flatten the documented burst), and the
    capacity cap never binds."""
    base = workload.base_rates(np.asarray(ENV.er).sum(axis=1))
    for seed in range(30):
        car = workload.arrival_pattern("bursty", base, seed=seed,
                                       resample=False)
        shape = car[0] / base[0]  # the shared 24-h shape
        spiked = shape[shape > 0.30 + 1e-9]
        assert len(spiked) >= 2, seed  # at least two spike hours landed
        assert np.all(spiked >= 0.30 * 2.0 - 1e-6), (seed, spiked)
        assert np.all(spiked <= 0.30 * 3.3 + 1e-6), (seed, spiked)  # < cap
        base_hours = shape[shape <= 0.30 + 1e-9]
        np.testing.assert_allclose(base_hours, 0.30)


def test_build_env_routes_through_base_rates():
    """build_env's arrival construction == workload.base_rates + pattern."""
    env = E.build_env(4, seed=5, pattern="weekday")
    base = workload.base_rates(np.asarray(env.er).sum(axis=1))
    expect = workload.arrival_pattern("weekday", base, seed=5)
    np.testing.assert_allclose(np.asarray(env.car), expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# compiled day engine vs. the reference loop
# ---------------------------------------------------------------------------

FD_CFG = FDConfig(iters=60)
NASH_CFG = NashConfig(sweeps=3, inner_steps=20)


@pytest.mark.parametrize("technique,cfg", [("fd", FD_CFG), ("nash", NASH_CFG)])
def test_scan_engine_matches_loop(technique, cfg):
    loop = SCH.run_day(ENV, technique, seed=0, hours=24, cfg_override=cfg,
                       engine="loop")
    scan = SCH.run_day(ENV, technique, seed=0, hours=24, cfg_override=cfg,
                       engine="scan")
    for k in ("carbon_kg", "cost_usd", "violation"):
        a, b = loop["totals"][k], scan["totals"][k]
        assert abs(a - b) <= 1e-5 * max(abs(a), 1.0), (k, a, b)
    for lrow, srow in zip(loop["per_epoch"], scan["per_epoch"]):
        for k in ("carbon_kg", "cost_usd"):
            assert abs(lrow[k] - srow[k]) <= 1e-4 * max(abs(lrow[k]), 1.0)


def test_batched_engine_matches_single_scan_across_suite():
    suite = S.build_suite("stress", ENV)
    envs = [env for _, env in suite]
    assert len(envs) >= 8
    batch = SCH.run_days_batched(envs, "fd", seeds=[0] * len(envs),
                                 cfg_override=FD_CFG)
    assert batch["totals"]["carbon_kg"].shape == (len(envs),)
    assert batch["per_epoch"]["carbon_kg"].shape == (len(envs), 24)
    # spot-check two scenario-days against the single-day scan engine
    for idx in (0, 2):
        single = SCH.run_day(envs[idx], "fd", seed=0, cfg_override=FD_CFG)
        np.testing.assert_allclose(batch["totals"]["carbon_kg"][idx],
                                   single["totals"]["carbon_kg"], rtol=1e-4)
    assert np.all(np.isfinite(batch["totals"]["cost_usd"]))


def test_scenarios_change_metrics_in_the_right_direction():
    base = SCH.run_day(ENV, "fd", seed=0, cfg_override=FD_CFG)
    spike = SCH.run_day(S.Scenario("carbon_spike", {"magnitude": 3.0}).apply(ENV),
                        "fd", seed=0, cfg_override=FD_CFG)
    surge = SCH.run_day(S.Scenario("price_surge", {"magnitude": 3.0}).apply(ENV),
                        "fd", seed=0, cfg_override=FD_CFG)
    assert spike["totals"]["carbon_kg"] > base["totals"]["carbon_kg"]
    assert surge["totals"]["cost_usd"] > base["totals"]["cost_usd"]
