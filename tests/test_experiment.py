"""ExperimentSpec façade: spec-built engines reproduce the legacy entry
points bit-for-bit, the compile cache is shared across call sites, sweeps
at identity grid points equal the unswept run, sharded == unsharded on one
device, and external techniques plug in through the registry."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios as S
from repro.core import (ExperimentSpec, register_technique, run, run_day,
                        run_days_batched, run_month, sweep, technique_names)
from repro.core import experiment as X
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.core.game import SolveResult, get_technique, uniform_fractions
from repro.dcsim import env as E

ENV = E.build_env(4, seed=0)
FD_CFG = FDConfig(iters=40)
SPEC = ExperimentSpec(technique="fd", objective="carbon", hours=6, cfg=FD_CFG)


# ---------------------------------------------------------------------------
# the spec itself
# ---------------------------------------------------------------------------

def test_spec_is_frozen_hashable_and_replaceable():
    assert hash(SPEC) == hash(ExperimentSpec(technique="fd", hours=6, cfg=FD_CFG))
    assert SPEC.replace(hours=3).hours == 3
    assert SPEC.replace(hours=3) != SPEC
    with pytest.raises(dataclasses.FrozenInstanceError):
        SPEC.hours = 12
    # seeds normalize to a tuple so the spec stays hashable
    s = ExperimentSpec(seeds=[0, 1, 2])
    assert s.seeds == (0, 1, 2)
    hash(s)


def test_spec_validates_engine_and_objective_eagerly():
    with pytest.raises(ValueError):
        ExperimentSpec(engine="Batched")
    with pytest.raises(ValueError):
        ExperimentSpec(objective="co2")
    with pytest.raises(KeyError):
        run(ExperimentSpec(technique="not-a-solver"), ENV)


def test_run_rejects_mismatched_options():
    with pytest.raises(ValueError):
        run(SPEC, ENV, shard=True)  # shard needs engine="batched"
    with pytest.raises(ValueError):
        run(SPEC.replace(engine="batched"), [ENV],
            solver=lambda *a: None)  # prebuilt solver needs engine="loop"


# ---------------------------------------------------------------------------
# bit-for-bit parity: legacy entry points == the spec path
# ---------------------------------------------------------------------------

def test_spec_scan_matches_run_day_bit_for_bit():
    legacy = run_day(ENV, "fd", seed=0, hours=6, cfg_override=FD_CFG)
    spec = run(SPEC, ENV)
    assert legacy["totals"] == spec["totals"]
    assert legacy["per_epoch"] == spec["per_epoch"]


def test_spec_batched_matches_run_days_batched_bit_for_bit():
    envs = [e for _, e in S.build_suite("baseline", ENV)][:3]
    legacy = run_days_batched(envs, "fd", hours=6, cfg_override=FD_CFG)
    spec = run(SPEC.replace(engine="batched"), envs)
    for k in legacy["totals"]:
        np.testing.assert_array_equal(legacy["totals"][k], spec["totals"][k])
    assert legacy["seeds"] == spec["seeds"]


def test_spec_month_matches_run_month_bit_for_bit():
    legacy = run_month(ENV, "fd", days=3, seed=0, hours=6, cfg_override=FD_CFG)
    spec = run(SPEC.replace(engine="month", days=3), ENV)
    for k in legacy["day_totals"]:
        np.testing.assert_array_equal(legacy["day_totals"][k],
                                      spec["day_totals"][k])
    np.testing.assert_array_equal(legacy["peak_w"], spec["peak_w"])


def test_spec_loop_matches_run_day_loop_bit_for_bit():
    legacy = run_day(ENV, "fd", seed=0, hours=3, cfg_override=FD_CFG,
                     engine="loop")
    spec = run(SPEC.replace(engine="loop", hours=3), ENV)
    assert legacy["totals"] == spec["totals"]


# ---------------------------------------------------------------------------
# the spec-keyed compile cache is shared across call sites
# ---------------------------------------------------------------------------

def test_compile_cache_shared_across_entry_points():
    """run_day, run(spec) and compare_techniques with the same static fields
    must all reuse ONE compiled day — no per-call-site compile paths."""
    spec = SPEC.replace(hours=4)
    run(spec, ENV)
    size0 = X._compiled.cache_info().currsize
    hits0 = X._compiled.cache_info().hits
    run_day(ENV, "fd", seed=3, hours=4, cfg_override=FD_CFG)  # legacy shim
    run(spec.replace(seed=7, pretrain=False), ENV)  # runtime fields differ
    info = X._compiled.cache_info()
    assert info.currsize == size0          # no new compiled artifact
    assert info.hits >= hits0 + 2          # both calls hit the shared cache
    assert X.compiled_engine(spec) is X.compiled_engine(spec.replace(seed=9))


def test_non_static_fields_never_key_a_new_compile():
    """Every field outside ``static_key()`` (seed, seeds, days, pretrain) is
    a runtime input: varying them must hit the SAME compiled artifact, and
    the obs accounting must agree (one miss total, the rest hits)."""
    from repro import obs
    spec = SPEC.replace(hours=3)
    key = X._engine_key(spec)
    before = obs.engine_stat(key) or {"misses": 0, "hits": 0}
    fn = X.compiled_engine(spec)
    for other in (spec.replace(seed=41), spec.replace(pretrain=False),
                  spec.replace(seed=7, pretrain=False)):
        assert X.compiled_engine(other) is fn
    mspec = spec.replace(engine="month", days=2)
    mfn = X.compiled_engine(mspec)
    assert X.compiled_engine(mspec.replace(days=5, seed=3)) is mfn
    # the obs ledger tells the same story: at most one fresh miss on the day
    # key, and every non-static variation above counted as a hit
    st = obs.engine_stat(key)
    assert st["misses"] <= before["misses"] + 1
    assert st["hits"] >= before["hits"] + 3


def test_overwrite_eviction_lands_in_cache_stats():
    """``register_technique(overwrite=True)`` clears the compile caches; the
    obs accounting must surface that as evictions + a fresh miss, not keep
    counting hits against a dead artifact."""
    from repro import obs
    register_technique("evict-test", _uniform_solve)
    try:
        spec = ExperimentSpec(technique="evict-test", hours=2)
        run(spec, ENV)
        key = X._engine_key(spec)
        assert obs.engine_stat(key)["misses"] == 1
        ev0 = obs.cache_stats()["evictions"]
        register_technique("evict-test", _uniform_solve, overwrite=True)
        assert obs.cache_stats()["evictions"] > ev0
        assert obs.engine_stat(key)["evicted"]
        run(spec, ENV)  # recompiles: the ledger shows a second miss
        assert obs.engine_stat(key)["misses"] == 2
    finally:
        from repro.core import unregister_technique
        unregister_technique("evict-test")


# ---------------------------------------------------------------------------
# severity sweeps
# ---------------------------------------------------------------------------

def test_expand_grid_scalars_map_to_severity_knobs():
    pts = S.expand_grid({"wan_degradation": (1.0, 3.0),
                         "origin_shift": ({"weight": 0.5, "toward": (1,)},)})
    assert pts == [
        {"wan_degradation": {"factor": 1.0},
         "origin_shift": {"weight": 0.5, "toward": (1,)}},
        {"wan_degradation": {"factor": 3.0},
         "origin_shift": {"weight": 0.5, "toward": (1,)}},
    ]
    with pytest.raises(KeyError):
        S.expand_grid({"not_a_transform": (1.0,)})
    with pytest.raises(ValueError):
        S.severity_knob("identity")  # no declared knob -> explicit dicts only


def test_sweep_identity_point_matches_unswept_run():
    """An origin_shift weight-0 grid point is the identity transform, so its
    curve must equal the unswept batched run on the same base env."""
    base = (S.Scenario("sla_tighten", {"tighten": 0.8}),
            S.Scenario("wan_degradation", {"factor": 2.0, "extra_ms": 20.0}))
    spec = SPEC.replace(objective="cost_sla", routed=True, hours=4)
    res = sweep(spec, {"origin_shift": (0.0, 0.7)}, base_env=ENV,
                base_scenarios=base)
    unswept = run(spec.replace(engine="batched", seeds=(spec.seed,)),
                  S.apply_all(ENV, base))
    for k in ("carbon_kg", "cost_usd", "sla_miss_cost_usd"):
        np.testing.assert_allclose(res["results"]["fd"]["totals"][k][0],
                                   unswept["totals"][k][0], rtol=1e-6)
    # the shifted point must actually differ (the routed game sees origins)
    assert not np.allclose(res["results"]["fd"]["totals"]["sla_miss_cost_usd"][0],
                           res["results"]["fd"]["totals"]["sla_miss_cost_usd"][1])


def test_sweep_returns_per_point_curves_for_each_technique():
    grid = {"wan_degradation": (1.0, 4.0), "origin_shift": (0.0, 0.8)}
    from repro.core.nash import NashConfig
    spec = SPEC.replace(objective="cost_sla", routed=True, hours=3)
    res = sweep(spec, grid, base_env=ENV, techniques=("fd", "nash"),
                cfg_overrides={"nash": NashConfig(sweeps=2, inner_steps=10)},
                base_scenarios=(S.Scenario("sla_tighten", {"tighten": 0.7}),))
    assert res["labels"] == ["wan_degradation=1.0|origin_shift=0.0",
                             "wan_degradation=1.0|origin_shift=0.8",
                             "wan_degradation=4.0|origin_shift=0.0",
                             "wan_degradation=4.0|origin_shift=0.8"]
    for t in ("fd", "nash"):
        assert res["results"][t]["per_epoch"]["cost_usd"].shape == (4, 3)
        assert res["results"][t]["totals"]["sla_miss_cost_usd"].shape == (4,)
    # severity curves are monotone here: a 4x-degraded WAN costs more SLA
    sla = res["results"]["fd"]["totals"]["sla_miss_cost_usd"]
    assert sla[2] > sla[0] and sla[3] > sla[1]


# ---------------------------------------------------------------------------
# device-sharded batched engine
# ---------------------------------------------------------------------------

def test_sharded_run_matches_unsharded_on_one_device():
    envs = [e for _, e in S.build_suite("baseline", ENV)][:3]
    spec = SPEC.replace(engine="batched", hours=4)
    plain = run(spec, envs)
    sharded = run(spec, envs, shard=True)
    for k in plain["totals"]:
        np.testing.assert_array_equal(plain["totals"][k], sharded["totals"][k])
    for k in plain["per_epoch"]:
        np.testing.assert_array_equal(plain["per_epoch"][k],
                                      sharded["per_epoch"][k])


def test_pad_env_batch_repeats_last_row_and_validates():
    env_b = E.stack_envs([ENV, S.make("carbon_spike")(ENV)])
    padded = E.pad_env_batch(env_b, 5)
    assert padded.er.shape[0] == 5
    np.testing.assert_array_equal(np.asarray(padded.carbon[4]),
                                  np.asarray(padded.carbon[1]))
    assert E.pad_env_batch(env_b, 2) is env_b
    with pytest.raises(ValueError):
        E.pad_env_batch(env_b, 1)


# ---------------------------------------------------------------------------
# technique registry: external solvers plug into the spec by name
# ---------------------------------------------------------------------------

def _uniform_solve(key, ctx, peak_state, cfg=None):
    return SolveResult(uniform_fractions(ctx), {})


def test_register_technique_plugs_into_every_engine():
    register_technique("uniform-test", _uniform_solve)
    try:
        assert "uniform-test" in technique_names()
        spec = ExperimentSpec(technique="uniform-test", hours=3)
        day = run(spec, ENV)
        assert day["totals"]["violation"] < 1e-3
        bat = run(spec.replace(engine="batched"), [ENV, ENV])
        np.testing.assert_array_equal(bat["totals"]["carbon_kg"][0],
                                      bat["totals"]["carbon_kg"][1])
        cmp_res = SCH.compare_techniques([ENV], ("uniform-test",), hours=3)
        np.testing.assert_allclose(cmp_res["uniform-test"]["mean"],
                                   day["totals"]["carbon_kg"], rtol=1e-6)
        # loop engine resolves registered names through get_scheduler too
        loop = run(spec.replace(engine="loop"), ENV)
        np.testing.assert_allclose(loop["totals"]["carbon_kg"],
                                   day["totals"]["carbon_kg"], rtol=1e-5)
    finally:
        from repro.core import unregister_technique
        unregister_technique("uniform-test")


def test_register_technique_rejects_duplicates_and_bad_shapes():
    with pytest.raises(KeyError):
        register_technique("fd", _uniform_solve)
    with pytest.raises(ValueError):
        register_technique("both", _uniform_solve, step=lambda *a: None)
    with pytest.raises(ValueError):
        register_technique("neither")
    with pytest.raises(KeyError):
        get_technique("never-registered")


def test_reregistration_with_overwrite_clears_compile_caches():
    register_technique("overwrite-test", _uniform_solve)
    try:
        spec = ExperimentSpec(technique="overwrite-test", hours=2)
        base = run(spec, ENV)["totals"]["carbon_kg"]

        def degenerate(key, ctx, peak_state, cfg=None):
            f = jnp.zeros(ctx.joint_shape()).at[..., 0].set(1.0)
            return SolveResult(f, {})

        register_technique("overwrite-test", degenerate, overwrite=True)
        rebound = run(spec, ENV)["totals"]["carbon_kg"]
        assert rebound != base  # stale compiled engine would return `base`
    finally:
        from repro.core import unregister_technique
        unregister_technique("overwrite-test")


def test_external_stateful_technique_scan_matches_loop():
    """Scan and loop engines must build an external stateful technique's
    carry with the SAME key discipline (pretrain flag included), so the
    all-engines-match contract holds beyond gt-drl."""
    import jax

    def _init(key, env, objective, cfg, routed, pretrain):
        # key-derived carry: any engine key-discipline divergence shows up
        return jax.random.normal(key, (E.num_dcs(env),))

    def _step(key, state, ctx, peak_state, cfg):
        row = jnp.broadcast_to(jax.nn.softmax(state), ctx.joint_shape())
        return state + 0.1, SolveResult(row, {})

    register_technique("stateful-test", step=_step, init_state=_init,
                       stateful=True)
    try:
        spec = ExperimentSpec(technique="stateful-test", hours=3, seed=5,
                              pretrain=False)
        scan = run(spec, ENV)
        loop = run(spec.replace(engine="loop"), ENV)
        for k in ("carbon_kg", "cost_usd", "violation"):
            np.testing.assert_allclose(loop["totals"][k], scan["totals"][k],
                                       rtol=1e-5)
    finally:
        from repro.core import unregister_technique
        unregister_technique("stateful-test")
