import os
import sys

# tests must see exactly ONE device (the dry-run sets 512 in its own process)
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must run without the dry-run's device-count override"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture
def expect_compiles():
    """The runtime compile-count sanitizer (``repro.lint``) as a fixture:
    ``with expect_compiles(n): run(...)`` asserts the block builds exactly
    ``n`` engine artifacts (and names the forking keys when it doesn't)."""
    from repro import lint
    return lint.expect_compiles


# hypothesis is optional (see requirements-dev.txt); property tests fall back
# to the deterministic sampler in tests/_hyp_compat.py when it is absent.
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
