"""Estimator-vs-simulator cost reconciliation + the SLA/latency subsystem.

The optimization estimator (eqs. 10–18, ``cct_est``/``cet_est``) and the
detailed simulator (``step_epoch``) price the same physics: summed over
players, the estimator's energy/peak/network(/SLA) components must equal
the detailed metrics within float32 tolerance on any loaded assignment.
The seed broke this three ways (network $ off 1000×, the monthly-peak
delta charged I times, the CRAC cap blind to ``avail``); these tests pin
the reconciled behavior, plus the latency model's invariants and the
``cost_sla`` objective through both day engines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios as S
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.dcsim import env as E
from repro.dcsim import latency as L

ENV4 = E.build_env(4, seed=0)
ENV8 = E.build_env(8, seed=1)
FD_CFG = FDConfig(iters=60)

SLA_ENV = S.make("wan_degradation")(
    S.make("sla_tighten", tighten=0.6, price=1e-4)(ENV4))


def _random_feasible_ar(env, seed, tau):
    """Strictly positive random fractions -> every DC carries load."""
    key = jax.random.PRNGKey(seed)
    f = jax.random.uniform(key, env.er.shape, minval=0.05, maxval=1.0)
    return E.project_feasible(env, f / f.sum(axis=1, keepdims=True), tau)


# ---------------------------------------------------------------------------
# estimator vs detailed simulator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("env,tau,seed", [
    (ENV4, 3, 0), (ENV4, 12, 1), (ENV4, 20, 2), (ENV8, 9, 3), (ENV8, 17, 4),
])
def test_cost_estimator_matches_detailed_simulator(env, tau, seed):
    """Σ_i CCT (eq. 18) == step_epoch energy + peak + network costs."""
    ar = _random_feasible_ar(env, seed, tau)
    d = E.num_dcs(env)
    peak = 0.3 * float(jnp.max(E.dp_max_t(env, tau))) * jnp.linspace(0.0, 1.0, d)
    _, m = E.step_epoch(env, peak, ar, tau)
    detailed = float(m["energy_cost_usd"] + m["peak_cost_usd"]
                     + m["network_cost_usd"])
    est = float(jnp.sum(E.cct_est(env, ar, tau, peak)))
    np.testing.assert_allclose(est, detailed, rtol=1e-5)


@pytest.mark.parametrize("env,tau", [(ENV4, 7), (ENV8, 15)])
def test_carbon_estimator_matches_detailed_simulator(env, tau):
    """Σ_i CET (eq. 13) == step_epoch carbon: the load-share attribution
    reconciles the carbon estimate too."""
    ar = _random_feasible_ar(env, 5, tau)
    _, m = E.step_epoch(env, jnp.zeros((E.num_dcs(env),)), ar, tau)
    np.testing.assert_allclose(float(E.ce_est(env, ar, tau)),
                               float(m["carbon_kg"]), rtol=1e-5)


def test_sla_estimator_matches_detailed_simulator():
    """The SLA term reconciles the same way on an SLA-priced env."""
    tau = 18
    ar = _random_feasible_ar(SLA_ENV, 6, tau)
    peak = jnp.zeros((4,))
    _, m = E.step_epoch(SLA_ENV, peak, ar, tau)
    assert float(m["sla_miss_cost_usd"]) > 0.0
    np.testing.assert_allclose(float(jnp.sum(E.sla_cost_est(SLA_ENV, ar, tau))),
                               float(m["sla_miss_cost_usd"]), rtol=1e-5)
    est = float(jnp.sum(E.player_reward(SLA_ENV, ar, tau, peak, "cost_sla")))
    detailed = float(m["energy_cost_usd"] + m["peak_cost_usd"]
                     + m["network_cost_usd"] + m["sla_miss_cost_usd"])
    np.testing.assert_allclose(est, detailed, rtol=1e-5)


def test_routed_sla_estimator_matches_detailed_simulator():
    """Σ-estimator == simulator still holds with the SLA term priced per
    (source, task): the routed reward decomposes into the same detailed
    bills as the unrouted one."""
    tau = 18
    env = S.make("origin_shift", toward=[0], weight=0.8)(SLA_ENV)
    f = jax.random.dirichlet(jax.random.PRNGKey(12),
                             jnp.ones((4, 10, 4)) * 2.0)
    ar3 = E.project_feasible_routed(env, f, tau)
    peak = jnp.zeros((4,))
    _, m = E.step_epoch(env, peak, ar3, tau)
    assert float(m["sla_miss_cost_usd"]) > 0.0
    np.testing.assert_allclose(
        float(jnp.sum(E.sla_cost_est_routed(env, ar3, tau))),
        float(m["sla_miss_cost_usd"]), rtol=1e-5)
    est = float(jnp.sum(E.player_reward(env, ar3, tau, peak, "cost_sla")))
    detailed = float(m["energy_cost_usd"] + m["peak_cost_usd"]
                     + m["network_cost_usd"] + m["sla_miss_cost_usd"])
    np.testing.assert_allclose(est, detailed, rtol=1e-5)


def test_network_cost_units():
    """$/GB × GB/task × tasks/h — no spurious 1/1000 anywhere."""
    tau = 10
    ar = _random_feasible_ar(ENV4, 7, tau)
    _, m = E.step_epoch(ENV4, jnp.zeros((4,)), ar, tau)
    expect = float(jnp.sum(ENV4.nprice * ENV4.sizes[:, None] * ar))
    np.testing.assert_allclose(float(m["network_cost_usd"]), expect, rtol=1e-6)
    np.testing.assert_allclose(float(jnp.sum(E.nc_est(ENV4, ar))), expect,
                               rtol=1e-6)


def test_peak_delta_attributed_once_not_per_player():
    """The monthly-peak delta is split across players by load share: summed
    player deltas == the fleet delta (the seed charged it I times)."""
    tau = 12
    ar = _random_feasible_ar(ENV4, 8, tau)
    peak = jnp.zeros((4,))
    delta, _ = E.peak_increase(ENV4, ar, tau, peak)
    with_peak = E.cct_est(ENV4, ar, tau, peak)
    # a peak state above any draw -> zero delta; the difference is the charge
    no_delta = E.cct_est(ENV4, ar, tau, peak + 1e9)
    np.testing.assert_allclose(float(jnp.sum(with_peak - no_delta)),
                               float(jnp.sum(delta)), rtol=1e-4)


def test_crac_cap_scales_with_avail():
    """A 50%-curtailed DC models 50% cooling headroom, not full (the cap
    only binds on oversized IT loads, so build one)."""
    env = ENV4._replace(it_dyn=ENV4.it_dyn * 8.0)
    tau = 6
    full = np.asarray(E.dp_max_t(env, tau))
    it_full = np.asarray((env.it_idle + env.it_dyn))
    assert np.any(it_full / np.asarray(E.power_cop(env))
                  > np.asarray(E.crac_cap_t(env, tau))), "cap must bind"
    half = env._replace(avail=env.avail * 0.5)
    got = np.asarray(E.dp_max_t(half, tau))
    it = it_full * 0.5
    crac = np.minimum(it / np.asarray(E.power_cop(env)),
                      np.asarray(E.crac_cap_t(half, tau)))
    expect = (it + crac) * np.asarray(env.eff) - np.asarray(env.rp[:, tau])
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    assert np.all(got < full)


# ---------------------------------------------------------------------------
# latency model invariants
# ---------------------------------------------------------------------------

def test_latency_monotone_in_utilization():
    tau = 14
    ar = _random_feasible_ar(SLA_ENV, 9, tau)
    lat_full = E.latency_ms(SLA_ENV, ar, tau)
    lat_half = E.latency_ms(SLA_ENV, ar * 0.5, tau)
    lat_zero = E.latency_ms(SLA_ENV, jnp.zeros_like(ar), tau)
    assert bool(jnp.all(lat_zero <= lat_half + 1e-9))
    assert bool(jnp.all(lat_half <= lat_full + 1e-9))
    assert bool(jnp.any(lat_half < lat_full))  # strictly on loaded DCs
    # zero load == access RTT + pure service share
    expect0 = (L.access_ms(SLA_ENV.rtt)[None, :]
               + L.service_ms(SLA_ENV.er, SLA_ENV.nn_total))
    np.testing.assert_allclose(np.asarray(lat_zero), np.asarray(expect0),
                               rtol=1e-6)


def test_sla_terms_zero_at_paper_defaults():
    """Default env (rtt=0, sla_price=0): the SLA bill is exactly zero and
    cost_usd decomposes exactly as energy + peak + network."""
    tau = 16
    ar = _random_feasible_ar(ENV4, 10, tau)
    peak = jnp.zeros((4,))
    _, m = E.step_epoch(ENV4, peak, ar, tau)
    assert float(m["sla_miss_cost_usd"]) == 0.0
    assert float(m["cost_usd"]) == float(m["energy_cost_usd"]
                                         + m["peak_cost_usd"]
                                         + m["network_cost_usd"])
    r_cost = E.player_reward(ENV4, ar, tau, peak, "cost")
    r_sla = E.player_reward(ENV4, ar, tau, peak, "cost_sla")
    np.testing.assert_array_equal(np.asarray(r_cost), np.asarray(r_sla))


def test_player_reward_rejects_unknown_objective():
    ar = _random_feasible_ar(ENV4, 0, 0)
    with pytest.raises(ValueError):
        E.player_reward(ENV4, ar, 0, jnp.zeros((4,)), "latency")


def test_rtt_matrix_geometry():
    rtt = L.rtt_matrix(num_dcs=4)  # NY, SF, Dallas, Seattle
    assert rtt.shape == (4, 4)
    np.testing.assert_allclose(rtt, rtt.T)
    assert np.all(np.diag(rtt) == 0.0)
    off = rtt[~np.eye(4, dtype=bool)]
    assert np.all(off > 0)
    # coast-to-coast (NY-SF) must out-delay NY-Dallas
    assert rtt[0, 1] > rtt[0, 2]
    assert np.all(off < 300.0)  # continental US stays under 300 ms


def test_location_coords_pins_known_city_pair_rtt():
    """The named coordinate accessor + a pinned NY–SF distance/RTT: if the
    LOCATIONS schema moves the (lat, lon) columns, this breaks loudly
    instead of silently corrupting the whole RTT matrix."""
    from repro.dcsim import topology as T
    lat, lon = T.location_coords([0, 1])  # new-york, san-francisco
    np.testing.assert_allclose(lat, [40.71, 37.77])
    np.testing.assert_allclose(lon, [-74.01, -122.42])
    d_km = L.haversine_km(lat, lon)[0, 1]
    np.testing.assert_allclose(d_km, 4129.1, rtol=1e-3)  # great-circle NY–SF
    rtt = L.rtt_matrix(num_dcs=4)
    # 2 × (4129.1 km × 1.4 stretch / 200 km/ms + 2 ms hop) ≈ 61.8 ms
    np.testing.assert_allclose(rtt[0, 1], 61.8, rtol=1e-2)
    lat_all, lon_all = T.location_coords()
    assert lat_all.shape == lon_all.shape == (len(T.LOCATIONS),)
    # continental US bounding box: a schema shuffle lands outside it
    assert np.all((24 < lat_all) & (lat_all < 49))
    assert np.all((-125 < lon_all) & (lon_all < -66))


def test_wan_degradation_raises_latency_metric():
    tau = 12
    ar = _random_feasible_ar(ENV4, 11, tau)
    base = S.make("sla_tighten")(ENV4)
    degraded = S.make("wan_degradation", factor=3.0, extra_ms=30.0)(base)
    _, m0 = E.step_epoch(base, jnp.zeros((4,)), ar, tau)
    _, m1 = E.step_epoch(degraded, jnp.zeros((4,)), ar, tau)
    assert float(m1["latency_ms"]) > float(m0["latency_ms"])


def test_sla_tighten_scales_targets_and_prices():
    env = S.make("sla_tighten", tighten=0.5, price=2e-4, weight=3.0,
                 tasks=[0, 4])(ENV4)
    sla = np.asarray(env.sla_ms)
    np.testing.assert_allclose(sla[[0, 4]], np.asarray(ENV4.sla_ms)[[0, 4]] * 0.5)
    np.testing.assert_allclose(sla[1], np.asarray(ENV4.sla_ms)[1])
    price = np.asarray(env.sla_price)
    assert price[0] == pytest.approx(2e-4) and price[1] == 0.0
    assert float(env.sla_weight) == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# cost_sla through the engines
# ---------------------------------------------------------------------------

def test_scan_matches_loop_with_cost_sla():
    loop = SCH.run_day(SLA_ENV, "fd", "cost_sla", seed=0, hours=6,
                       cfg_override=FD_CFG, engine="loop")
    scan = SCH.run_day(SLA_ENV, "fd", "cost_sla", seed=0, hours=6,
                       cfg_override=FD_CFG, engine="scan")
    for k in ("carbon_kg", "cost_usd", "sla_miss_cost_usd", "violation"):
        a, b = loop["totals"][k], scan["totals"][k]
        assert abs(a - b) <= 1e-4 * max(abs(a), 1.0), (k, a, b)


def test_latency_suite_batched_and_month_with_cost_sla():
    """The latency suite runs in one vmapped compile; the SLA metrics flow
    through run_days_batched and run_month unchanged."""
    suite = S.build_suite("latency", ENV4)
    envs = [e for _, e in suite]
    res = SCH.run_days_batched(envs, "fd", "cost_sla", hours=4,
                               cfg_override=FD_CFG)
    n = len(envs)
    assert res["totals"]["sla_miss_cost_usd"].shape == (n,)
    assert res["per_epoch"]["latency_ms"].shape == (n, 4)
    assert np.all(np.isfinite(res["totals"]["cost_usd"]))
    assert np.all(res["totals"]["sla_miss_cost_usd"] > 0)
    names = [nm for nm, _ in suite]
    wan = res["per_epoch"]["latency_ms"][names.index("wan-degraded")].mean()
    base = res["per_epoch"]["latency_ms"][names.index("sla-baseline")].mean()
    assert wan > base

    m = SCH.run_month(SLA_ENV, "fd", "cost_sla", days=2, hours=4,
                      cfg_override=FD_CFG)
    assert m["day_totals"]["sla_miss_cost_usd"].shape == (2,)
    assert np.isfinite(m["totals"]["sla_miss_cost_usd"])
