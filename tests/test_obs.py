"""The telemetry subsystem (``repro.obs``): taps are provably free when off
(bit-for-bit outputs, zero new compiles), faithful when on (tap series ==
the engine's own per-epoch metrics), the compile-cache accounting tracks
hits/misses/evictions, run records round-trip with full provenance, and the
scoreboard renders from records alone."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import ExperimentSpec, run
from repro.core import experiment as X
from repro.core import gt_drl
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.dcsim import env as E

ENV = E.build_env(4, seed=0)
FD_CFG = FDConfig(iters=40)
SPEC = ExperimentSpec(technique="fd", objective="carbon", hours=4, cfg=FD_CFG)


# ---------------------------------------------------------------------------
# the zero-cost-when-off contract (the tentpole's acceptance criterion)
# ---------------------------------------------------------------------------

def test_taps_off_is_bit_identical_and_compiles_nothing_new():
    """Running with taps disabled after a tapped run must (a) reproduce the
    taps-off totals bit-for-bit and (b) add zero compiled artifacts — the
    tapped engine is a SEPARATE cache entry, not a mutation of the silent
    one."""
    off = SPEC.replace(taps=())
    base = run(off, ENV)

    on = SPEC.replace(taps=("engine/hour",))
    with obs.capture("engine/hour") as buf:
        tapped = run(on, ENV)
    assert len(buf.events) == SPEC.hours  # one event per epoch

    key_off = X._engine_key(off)
    st0 = obs.engine_stat(key_off)
    again = run(off, ENV)
    st1 = obs.engine_stat(key_off)
    assert st1["misses"] == st0["misses"]     # zero new compiles, asserted
    assert st1["hits"] == st0["hits"] + 1     # via the obs ledger
    for k, v in base["totals"].items():
        assert again["totals"][k] == v        # bit-for-bit, not allclose
        np.testing.assert_allclose(tapped["totals"][k], v, rtol=1e-6)


def test_tapped_and_untapped_artifacts_coexist_under_distinct_keys():
    key_off = X._engine_key(SPEC.replace(taps=()))
    key_on = X._engine_key(SPEC.replace(taps=("engine/hour",)))
    assert key_off != key_on
    stats = obs.cache_stats()
    assert obs.engine_key_str(key_off) in stats["engines"]
    assert obs.engine_key_str(key_on) in stats["engines"]
    assert stats["engines"][obs.engine_key_str(key_on)]["dispatches"] >= 1


def test_tap_series_equals_engine_per_epoch_exactly():
    """The streamed engine/hour values ARE the engine's metrics — same
    arrays, routed out mid-scan — so the series matches per_epoch exactly."""
    spec = SPEC.replace(taps=("engine/hour",))
    with obs.capture("engine/hour") as buf:
        res = run(spec, ENV)
    for k in ("carbon_kg", "cost_usd", "sla_miss_cost_usd"):
        series = buf.series("engine/hour", k)
        expected = [row[k] for row in res["per_epoch"]]
        np.testing.assert_array_equal(series, np.asarray(expected))
    taus = buf.series("engine/hour", "tau")
    np.testing.assert_array_equal(taus, np.arange(spec.hours))


def test_shard_map_engine_rejects_taps():
    spec = ExperimentSpec(technique="fd", engine="batched", hours=2,
                          cfg=FD_CFG, taps=("engine/hour",))
    with pytest.raises(ValueError, match="shard"):
        run(spec, [ENV, ENV], shard=True)


# ---------------------------------------------------------------------------
# solver-trace taps
# ---------------------------------------------------------------------------

def test_nash_residual_tap_streams_finite_nonnegative_values():
    spec = SPEC.replace(hours=3, taps=("game/nash_residual",))
    with obs.capture() as buf:
        run(spec, ENV)
    res = buf.series("game/nash_residual", "residual")
    assert res.shape == (3,)
    assert np.all(np.isfinite(res)) and np.all(res >= 0.0)
    # the probe is only in the tapped artifact; taps-off streams nothing
    with obs.capture() as buf2:
        run(SPEC.replace(hours=3, taps=()), ENV)
    assert buf2.events == []


def test_gt_drl_taps_stream_round_and_ppo_diagnostics():
    from repro.core.ppo import PPOConfig
    cfg = gt_drl.GTDRLConfig(
        ppo=PPOConfig(horizon=2, episodes=4, iters=1, update_epochs=1),
        rounds=2, polish_steps=2, pretrain_iters=2)
    spec = ExperimentSpec(technique="gt-drl", hours=2, cfg=cfg,
                          taps=("gt_drl/*",))
    with obs.capture() as buf:
        run(spec, ENV)
    counts = buf.counts()
    i = E.num_players(ENV)
    assert counts["gt_drl/round"] == spec.hours * cfg.rounds
    assert counts["gt_drl/ppo"] == spec.hours * cfg.rounds * i
    deltas = buf.series("gt_drl/round", "delta")
    assert np.all(np.isfinite(deltas))
    losses = buf.series("gt_drl/ppo", "actor_loss")
    assert np.all(np.isfinite(losses))


def test_tap_pattern_matching_prefix_and_wildcard():
    assert obs.tap_mod._matches("engine/hour", frozenset(["engine/*"]))
    assert obs.tap_mod._matches("engine/hour", frozenset(["*"]))
    assert obs.tap_mod._matches("engine/hour", frozenset(["engine/hour"]))
    assert not obs.tap_mod._matches("engine/hour", frozenset(["gt_drl/*"]))
    assert not obs.tap_mod._matches("engine/hour", frozenset())


def test_ambient_taps_context_drives_spec_default():
    spec = SPEC.replace(hours=2)  # taps=None -> ambient
    assert spec.effective_taps() == frozenset()
    with obs.taps("engine/*"):
        assert spec.effective_taps() == frozenset({"engine/*"})
        with obs.capture("engine/hour") as buf:
            run(spec, ENV)
        assert len(buf.events) == 2
    assert spec.effective_taps() == frozenset()


# ---------------------------------------------------------------------------
# spans + cache accounting
# ---------------------------------------------------------------------------

def test_span_records_wall_time_into_the_stream():
    with obs.span("test/region", tag=1) as s:
        sum(range(1000))
    assert s.seconds > 0.0
    got = obs.all_spans("test/region")
    assert got and got[-1] is s and got[-1].meta == {"tag": 1}


def test_bench_timer_is_an_obs_span():
    from benchmarks.common import Timer, emit
    with Timer() as tm:
        sum(range(1000))
    assert isinstance(tm, obs.Span) and tm.seconds > 0.0
    rows = ["header"]
    emit(rows, "test/bench_row", 0.5, "derived=1")
    bench = [s for s in obs.all_spans("test/bench_row")
             if s.meta.get("kind") == "bench"]
    assert bench and bench[-1].seconds == 0.5


def test_cache_stats_dispatch_accounting():
    run(SPEC, ENV)
    st = obs.engine_stat(X._engine_key(SPEC))
    assert st["dispatches"] >= 1
    assert st["dispatch_s"] >= st["last_dispatch_s"] > 0.0
    assert st["first_dispatch_s"] > 0.0  # ≈ trace + XLA compile + run
    totals = obs.cache_stats()
    assert totals["misses"] >= 1 and totals["live_keys"] >= 1


def test_stats_single_run_stderr_is_zero_not_nan():
    """Regression: n=1 must report stderr 0.0 — the ddof=1 std is NaN at a
    single sample and would poison every downstream mean±stderr table."""
    out = SCH._stats([42.0], [[1.0, 2.0, 3.0]])
    assert out["mean"] == 42.0
    assert out["stderr"] == 0.0 and not np.isnan(out["stderr"])
    multi = SCH._stats([40.0, 44.0], [[1.0], [3.0]])
    assert multi["stderr"] > 0.0


# ---------------------------------------------------------------------------
# run records + the scoreboard
# ---------------------------------------------------------------------------

def test_run_record_roundtrip_with_provenance(tmp_path):
    path = str(tmp_path / "records.jsonl")
    res = run(SPEC, ENV, record=path)
    recs = obs.load_records(path)
    assert len(recs) == 1
    rec = recs[0]
    for field in ("git_sha", "jax_version", "backend", "device_kind",
                  "device_count", "cpu_count", "timestamp_utc"):
        assert field in rec, field
    assert rec["kind"] == "run"
    assert rec["spec"]["technique"] == "fd" and rec["spec"]["hours"] == 4
    assert rec["spec_key"] == obs.spec_key(SPEC)
    assert rec["totals"]["carbon_kg"] == res["totals"]["carbon_kg"]
    assert len(rec["curves"]["carbon_kg"]) == SPEC.hours
    assert rec["engine_spans"]["dispatches"] >= 1


def test_compare_techniques_emits_one_record_per_technique(tmp_path):
    path = str(tmp_path / "compare.jsonl")
    out = SCH.compare_techniques(
        [ENV], ("fd",), "carbon", hours=3, cfg_overrides={"fd": FD_CFG},
        record=path)
    recs = obs.load_records(path)
    assert len(recs) == 1 and recs[0]["kind"] == "compare"
    assert recs[0]["mean"] == out["fd"]["mean"]
    assert recs[0]["curves"]["carbon_kg"] == out["fd"]["curve_mean"]
    assert recs[0]["runs"] == 1 and recs[0]["stderr"] == 0.0


def test_sweep_emits_records_with_grid_labels(tmp_path):
    from repro.core import sweep
    path = str(tmp_path / "sweep.jsonl")
    spec = ExperimentSpec(technique="fd", objective="cost_sla",
                          engine="batched", hours=2, cfg=FD_CFG)
    sweep(spec, {"wan_degradation": (1.0, 2.0)}, base_env=ENV, record=path)
    recs = obs.load_records(path)
    assert len(recs) == 1 and recs[0]["kind"] == "sweep"
    assert len(recs[0]["labels"]) == 2


def test_report_renders_ranked_scoreboard(tmp_path):
    path = str(tmp_path / "records.jsonl")
    for t in ("fd", "ga"):
        spec = ExperimentSpec(technique=t, hours=3,
                              cfg=FD_CFG if t == "fd" else None)
        run(spec, ENV, record=path)
    md = obs.report(obs.load_records(path), title="test board")
    assert "test board" in md and "fd" in md and "ga" in md
    assert "carbon_kg" in md
    assert any(c in md for c in "▁▂▃▄▅▆▇█")  # convergence sparklines
    # one header + one row per technique in the carbon table
    rows = [ln for ln in md.splitlines()
            if ln.startswith("| ") and "technique" not in ln]
    assert len(rows) == 2
    # ranked: the lower-carbon technique's row comes first
    carbons = [float(ln.split("|")[4]) for ln in rows]
    assert carbons == sorted(carbons)


def test_sparkline_shapes():
    assert obs.sparkline([]) == ""
    assert len(obs.sparkline([1.0])) == 1
    s = obs.sparkline(list(range(32)), width=16)
    assert len(s) == 16 and s[0] == "▁" and s[-1] == "█"
    assert set(obs.sparkline([5.0, 5.0, 5.0])) <= set("▁▂▃▄▅▆▇█")


def test_bench_json_meta_carries_provenance():
    from benchmarks.run import rows_to_json
    payload = rows_to_json(["header", "x/y,12,d=1"], ("engine",), 1.0)
    meta = payload["meta"]
    for field in ("git_sha", "jax_version", "device_kind", "cpu_count"):
        assert field in meta, field
    assert payload["rows"] == [
        {"name": "x/y", "us_per_call": 12.0, "derived": "d=1"}]


def test_profile_writes_a_trace_or_degrades_gracefully(tmp_path):
    with obs.profile("unit", logdir=str(tmp_path)) as p:
        jnp.dot(jnp.ones((8, 8)), jnp.ones((8, 8))).block_until_ready()
    if p is not None:  # profiler available: the trace directory exists
        assert os.path.isdir(p)
