"""Optimizer + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, st

from repro.optim.adamw import (AdamWConfig, _dequantize, _quantize, adamw_init,
                               adamw_update, global_norm)
from repro.optim.grad_compress import compress_decompress, ef_compress_tree, init_error
from repro.optim.schedules import warmup_cosine


def _rosenbrock_params():
    return {"x": jnp.array([-1.2, 1.0, 0.5]), "y": {"z": jnp.array([2.0, -0.3])}}


def _loss(p):
    return jnp.sum((p["x"] - 1.0) ** 2) + 3.0 * jnp.sum(p["y"]["z"] ** 2)


@pytest.mark.parametrize("cfg", [
    AdamWConfig(lr=0.05, weight_decay=0.0),
    AdamWConfig(lr=0.05, weight_decay=0.0, quantize_moments=True),
    AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype="bfloat16"),
])
def test_adamw_converges(cfg):
    p = _rosenbrock_params()
    st_ = adamw_init(p, cfg)
    for _ in range(300):
        g = jax.grad(_loss)(p)
        p, st_, _ = adamw_update(g, st_, p, cfg)
    assert float(_loss(p)) < 1e-2


def test_grad_clip_limits_update_norm():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    p = {"x": jnp.ones((4,))}
    st_ = adamw_init(p, cfg)
    g = {"x": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(g, st_, p, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported raw


@given(st.lists(st.floats(-100, 100), min_size=3, max_size=64))
def test_quantize_roundtrip_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = _quantize(x)
    back = _dequantize(q, s, x.shape)
    blockmax = float(jnp.max(jnp.abs(x))) or 1.0
    assert float(jnp.max(jnp.abs(back - x))) <= blockmax / 127.0 + 1e-6


def test_error_feedback_is_lossless_over_time():
    """EF property: sum of compressed grads + final error == sum of raw grads."""
    key = jax.random.PRNGKey(0)
    grads = [{"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
             for i in range(20)]
    err = init_error(jax.eval_shape(lambda: grads[0]))
    sent = {"w": jnp.zeros((64,))}
    for g in grads:
        approx, err = ef_compress_tree(g, err)
        sent = {"w": sent["w"] + approx["w"]}
    total = {"w": sum(g["w"] for g in grads)}
    resid = float(jnp.max(jnp.abs(sent["w"] + err["w"] - total["w"])))
    assert resid < 1e-3


def test_compress_decompress_error_shrinks_signal():
    x = jax.random.normal(jax.random.PRNGKey(1), (256,))
    approx, err = compress_decompress(x)
    assert float(jnp.linalg.norm(err)) < 0.05 * float(jnp.linalg.norm(x))


def test_schedule_warmup_and_decay():
    s = warmup_cosine(jnp.arange(0, 1000), warmup=100, total=1000, floor=0.1)
    s = np.asarray(s)
    assert s[0] == 0.0
    assert abs(s[100] - 1.0) < 0.02
    assert s[-1] <= 0.2
    assert np.all(s >= 0)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5
