"""Property tests (hypothesis) for the datacenter environment invariants."""
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, st

from repro.dcsim import env as E
from repro.dcsim import colocation, power, topology

ENV4 = E.build_env(4, seed=0)
ENV8 = E.build_env(8, seed=1)


@st.composite
def fractions_strategy(draw, i=10, d=4):
    rows = draw(st.lists(
        st.lists(st.floats(0.01, 10.0), min_size=d, max_size=d),
        min_size=i, max_size=i))
    f = np.asarray(rows)
    return f / f.sum(axis=1, keepdims=True)


@given(fractions_strategy(), st.integers(0, 23))
def test_project_feasible_satisfies_constraints(fracs, tau):
    """Eq. (1): split sums to CAR; eq. (2): AR <= ER everywhere."""
    ar = E.project_feasible(ENV4, jnp.asarray(fracs, jnp.float32), tau)
    car = ENV4.car[:, tau]
    np.testing.assert_allclose(np.asarray(jnp.sum(ar, axis=1)), np.asarray(car),
                               rtol=2e-3)
    assert bool(jnp.all(ar <= ENV4.er * (1 + 1e-5)))
    assert bool(jnp.all(ar >= 0))


@given(st.integers(0, 23))
def test_peak_increase_monotone_and_nonnegative(tau):
    fr = jnp.full((10, 4), 0.25)
    ar = E.project_feasible(ENV4, fr, tau)
    peak0 = jnp.zeros((4,))
    delta0, peak1 = E.peak_increase(ENV4, ar, tau, peak0)
    assert bool(jnp.all(delta0 >= 0))
    # second epoch with the same load: no new peak charge
    delta1, peak2 = E.peak_increase(ENV4, ar, tau, peak1)
    assert float(jnp.sum(delta1)) < 1e-6
    assert bool(jnp.all(peak2 >= peak1))


@given(st.floats(0.1, 0.9), st.integers(0, 23))
def test_more_load_more_power(scale, tau):
    fr = jnp.full((10, 4), 0.25)
    ar = E.project_feasible(ENV4, fr, tau)
    p_full = E.grid_power(ENV4, ar, tau)
    p_less = E.grid_power(ENV4, ar * scale, tau)
    assert bool(jnp.all(p_less <= p_full + 1e-6))


def test_carbon_estimate_decomposition():
    """CE (eq. 13) == sum over players of CET (eq. 12)."""
    tau = 12
    fr = jnp.full((10, 4), 0.25)
    ar = E.project_feasible(ENV4, fr, tau)
    ce = float(E.ce_est(ENV4, ar, tau))
    cets = E.cet_est(ENV4, ar, tau)
    assert abs(ce - float(jnp.sum(cets))) < 1e-4 * abs(ce)


def test_renewables_reduce_net_power():
    env_hi = E.build_env(4, seed=0, renewable_scale=1.5)
    env_lo = E.build_env(4, seed=0, renewable_scale=0.1)
    tau = 20  # afternoon US: solar high somewhere
    fr = jnp.full((10, 4), 0.25)
    ar_hi = E.project_feasible(env_hi, fr, tau)
    ar_lo = E.project_feasible(env_lo, fr, tau)
    assert float(jnp.sum(E.grid_power(env_hi, ar_hi, tau))) < \
        float(jnp.sum(E.grid_power(env_lo, ar_lo, tau)))


def test_colocation_blowup_increases_with_intensity():
    coer = colocation.coer_core(3)
    bet = colocation.base_time_table(3)
    # co-located rate must be <= solo rate (1/bet) for every (i, j)
    solo = 1.0 / bet
    assert np.all(coer <= solo * 1.15 + 1e-9)
    # high-intensity classes lose more than low-intensity ones on the same node
    ratios = coer / solo
    low = [i for i, t in enumerate(topology.TASK_TYPES) if t[1] == 0]
    high = [i for i, t in enumerate(topology.TASK_TYPES) if t[1] == 2]
    assert ratios[high].mean() < ratios[low].mean()


def test_cop_model_positive_and_increasing():
    t = np.linspace(10, 30, 10)
    c = power.cop(t)
    assert np.all(c > 0)
    assert np.all(np.diff(c) > 0)


def test_step_epoch_metrics_finite_and_consistent():
    tau = 5
    fr = jnp.full((10, 8), 1.0 / 8)
    ar = E.project_feasible(ENV8, fr, tau)
    peak, m = E.step_epoch(ENV8, jnp.zeros((8,)), ar, tau)
    for k, v in m.items():
        assert bool(jnp.isfinite(v)), k
    assert float(m["cost_usd"]) >= float(m["network_cost_usd"]) - 1e-6
    assert float(m["max_rho"]) <= 1.0


def test_er_table_positive_and_heterogeneous():
    er = np.asarray(ENV8.er)
    assert np.all(er > 0)
    # heterogeneity: different DCs have different rates for the same task
    assert np.std(er, axis=1).min() > 0
