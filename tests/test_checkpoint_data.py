"""Checkpoint manager + data pipeline: atomicity, determinism, elasticity."""
import functools
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import TokenPipeline, make_batch
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainState, init_train_state, train_step

CFG = get_config("llama3.2-1b").smoke()
OPT = AdamWConfig(lr=1e-2)


def _mk_state(seed=0):
    return init_train_state(jax.random.PRNGKey(seed), CFG, OPT)


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _mk_state()
    mgr.save(7, {"params": state.params, "opt": state.opt}, extra={"k": 1})
    templates = {"params": jax.eval_shape(lambda: state.params),
                 "opt": jax.eval_shape(lambda: state.opt)}
    step, restored, extra = mgr.restore_latest(templates)
    assert step == 7 and extra == {"k": 1}
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _mk_state()
    mgr.save(5, {"params": state.params})
    # simulate a crash mid-write: directory without MANIFEST
    torn = tmp_path / "step_000000009"
    torn.mkdir()
    (torn / "params.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _mk_state()
    path = mgr.save(3, {"params": state.params})
    # flip bytes in the payload
    f = os.path.join(path, "params.npz")
    data = bytearray(open(f, "rb").read())
    data[100] ^= 0xFF
    open(f, "wb").write(bytes(data))
    with pytest.raises(IOError, match="sha256"):
        mgr.restore(3, {"params": jax.eval_shape(lambda: state.params)})


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _mk_state()
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": state.params})
    assert mgr.steps() == [3, 4]


def test_restart_bitwise_determinism(tmp_path):
    """Crash + restore + replay == uninterrupted run, bit for bit."""
    mgr = CheckpointManager(str(tmp_path))
    step_fn = jax.jit(functools.partial(train_step, cfg=CFG, opt_cfg=OPT),
                      donate_argnums=(0,))
    state = _mk_state()
    pipe = TokenPipeline(CFG, seed=9, batch=2, seq=32)
    for i in range(10):
        state, m = step_fn(state, pipe.next())
        if i == 4:
            mgr.save(5, {"params": state.params, "opt": state.opt},
                     extra={"data": pipe.state()})
    loss_a = float(m["loss"])
    # "crash": restore from step 5, replay 5 steps
    templates = {"params": jax.eval_shape(lambda: state.params),
                 "opt": jax.eval_shape(lambda: state.opt)}
    _, restored, extra = mgr.restore_latest(templates)
    state_b = TrainState(restored["params"], restored["opt"])
    pipe_b = TokenPipeline(CFG, seed=9, batch=2, seq=32)
    pipe_b.restore(extra["data"])
    for i in range(5):
        state_b, mb = step_fn(state_b, pipe_b.next())
    assert float(mb["loss"]) == loss_a


def test_pipeline_skip_ahead_determinism():
    p1 = TokenPipeline(CFG, seed=4, batch=2, seq=16)
    for _ in range(7):
        b_seq = p1.next()
    p2 = TokenPipeline(CFG, seed=4, batch=2, seq=16)
    p2.restore({"step": 6, "seed": 4})
    b_jump = p2.next()
    np.testing.assert_array_equal(np.asarray(b_seq["tokens"]), np.asarray(b_jump["tokens"]))


def test_pipeline_seed_mismatch_rejected():
    p = TokenPipeline(CFG, seed=4, batch=2, seq=16)
    with pytest.raises(AssertionError):
        p.restore({"step": 3, "seed": 5})


def test_batches_cover_modalities():
    for arch in ("whisper-base", "qwen2-vl-72b"):
        cfg = get_config(arch).smoke()
        b = make_batch(cfg, seed=0, step=0, batch=2, seq=16)
        if cfg.is_encoder_decoder:
            assert b["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)
        if cfg.rope_mode == "mrope":
            assert b["positions"].shape == (2, 16, 3)
        if cfg.frontend == "vision_stub":
            assert "vision_embeds" in b
