"""Recurrent-block math: mLSTM chunkwise == quadratic == stepwise; RG-LRU
associative scan == stepwise recurrence; hypothesis sweeps on shapes."""
import jax
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro.configs import get_config
from repro.models.rglru import (rglru_apply, rglru_decode, rglru_init, rglru_scan)
from repro.models.xlstm import mlstm_parallel, mlstm_sequence, mlstm_step
KEY = jax.random.PRNGKey(0)


def _mlstm_inputs(b, s, h, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    log_i = jax.random.normal(ks[3], (b, s, h)) * 2
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)) + 2)
    return q, k, v, log_i, log_f


@pytest.mark.parametrize("chunk", [8, 24, 64])
@pytest.mark.parametrize("s", [64, 96])
def test_mlstm_chunkwise_equals_parallel(chunk, s):
    q, k, v, li, lf = _mlstm_inputs(2, s, 4, 16)
    ref = mlstm_parallel(q, k, v, li, lf)
    out, _ = mlstm_sequence(q, k, v, li, lf, chunk=chunk)
    # fp32 accumulation-order error grows with |ref|; bound it relative to
    # the signal scale (2e-4 absolute is too tight for s>=96 on CPU)
    tol = 2e-4 * max(1.0, float(jnp.max(jnp.abs(ref))))
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_mlstm_state_handoff_to_decode():
    """Chunkwise prefill state + one recurrent step == parallel on s+1."""
    b, s, h, dh = 2, 48, 4, 16
    q, k, v, li, lf = _mlstm_inputs(b, s, h, dh)
    _, state = mlstm_sequence(q, k, v, li, lf, chunk=16)
    q1, k1, v1, li1, lf1 = (a[:, -1] for a in _mlstm_inputs(b, s, h, dh, seed=9)[:3]) \
        if False else (None,) * 5
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q1 = jax.random.normal(ks[0], (b, h, dh))
    k1 = jax.random.normal(ks[1], (b, h, dh))
    v1 = jax.random.normal(ks[2], (b, h, dh))
    li1 = jax.random.normal(ks[3], (b, h))
    lf1 = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h)) + 2)
    _, h_step = mlstm_step(state, q1, k1, v1, li1, lf1)
    full = mlstm_parallel(
        jnp.concatenate([q, q1[:, None]], 1), jnp.concatenate([k, k1[:, None]], 1),
        jnp.concatenate([v, v1[:, None]], 1), jnp.concatenate([li, li1[:, None]], 1),
        jnp.concatenate([lf, lf1[:, None]], 1))
    assert float(jnp.max(jnp.abs(h_step - full[:, -1]))) < 2e-4


def test_rglru_scan_equals_stepwise():
    b, s, w = 2, 40, 16
    ks = jax.random.split(KEY, 2)
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (b, s, w)))
    bb = jax.random.normal(ks[1], (b, s, w))
    h_scan = rglru_scan(log_a, bb)
    # sequential oracle
    h = jnp.zeros((b, w))
    outs = []
    for t in range(s):
        h = jnp.exp(log_a[:, t]) * h + bb[:, t]
        outs.append(h)
    ref = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(h_scan - ref))) < 1e-5


def test_rglru_block_prefill_decode_consistency():
    cfg = get_config("recurrentgemma-9b").smoke()
    p = rglru_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model)) * 0.3
    y_full = rglru_apply(p, cfg, x)
    y_pre, state = rglru_apply(p, cfg, x[:, :20], return_state=True)
    y = y_pre
    for t in range(20, 24):
        y_t, state = rglru_decode(p, cfg, x[:, t : t + 1], state)
        err = float(jnp.max(jnp.abs(y_t[:, 0] - y_full[:, t])))
        assert err < 1e-3, (t, err)


@given(st.integers(1, 4), st.integers(2, 30))
@settings(max_examples=10, deadline=None)
def test_mlstm_sequence_shape_property(b, s):
    q, k, v, li, lf = _mlstm_inputs(b, s, 2, 8, seed=s)
    out, state = mlstm_sequence(q, k, v, li, lf, chunk=8)
    assert out.shape == (b, s, 2, 8)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(jnp.isfinite(state["C"])))
