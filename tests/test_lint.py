"""``repro.lint``'s own tests: the tree is green, seeded regressions trip
the right checker (via ``Project.overlay`` — the working tree is never
touched), every static ``ExperimentSpec`` field forks the compile key while
runtime-only fields do not, the engines compile exactly once under repeated
identical ``run()`` calls, and live pytrees validate against their schemas.
"""
import jax.numpy as jnp
import pytest

from repro import lint
from repro.core import ExperimentSpec, run
from repro.core import experiment as X
from repro.core.force_directed import FDConfig
from repro.dcsim import env as E

PROJECT = lint.Project.load(lint.Project.default_root())

ENV = E.build_env(4, seed=0)
FD_CFG = FDConfig(iters=40)
SPEC = ExperimentSpec(technique="fd", objective="carbon", hours=6, cfg=FD_CFG)


def _seed(relpath: str, old: str, new: str) -> "lint.Project":
    """Overlay one source edit; the anchor must exist exactly once so the
    seeded regression is the edit we think it is."""
    sf = PROJECT.file(relpath)
    assert sf is not None, relpath
    assert sf.text.count(old) == 1, (relpath, old)
    return PROJECT.overlay(relpath, sf.text.replace(old, new))


def _hits(project, check: str, needle: str):
    return [v for v in lint.lint_project(project)
            if v.check == check and needle in v.message]


# ---------------------------------------------------------------------------
# the tree is green
# ---------------------------------------------------------------------------

def test_repo_lints_clean():
    violations = lint.lint_repo()
    assert violations == [], "\n".join(v.render() for v in violations)


# ---------------------------------------------------------------------------
# seeded regressions: each checker trips on the bug class it owns
# ---------------------------------------------------------------------------

def test_dropping_workload_from_static_key_trips_compile_key():
    p = _seed("src/repro/core/experiment.py",
              "self.routed, self.failover, self.guard, self.workload)",
              "self.routed, self.failover, self.guard)")
    assert _hits(p, "compile-key", "workload")


def test_tap_typo_trips_taps_checker():
    p = _seed("src/repro/core/experiment.py",
              'obs.tap("engine/hour"', 'obs.tap("engine/huor"')
    assert _hits(p, "taps", "engine/huor")          # undeclared emission
    assert _hits(p, "taps", "engine/hour")          # declared, never emitted


def test_host_clock_in_traced_root_trips_purity():
    p = _seed("src/repro/faults/failover.py",
              "    renv = realized_env(env, trace, tau)",
              "    import time\n    t0 = time.time()\n"
              "    renv = realized_env(env, trace, tau)")
    assert _hits(p, "purity", "time.time")


def test_np_random_in_solver_trips_purity():
    p = _seed("src/repro/core/gt_drl.py",
              '    """Run the game for one epoch: rounds',
              '    _bad = np.random.rand()\n'
              '    """Run the game for one epoch: rounds')
    assert _hits(p, "purity", "numpy.random")


def test_unclassified_spec_field_trips_compile_key():
    p = _seed("src/repro/core/experiment.py",
              '    technique: str = "fd"',
              '    precision: str = "f32"\n    technique: str = "fd"')
    assert _hits(p, "compile-key", "precision")


def test_syntax_error_is_reported_not_crashed():
    p = PROJECT.overlay("src/repro/core/gt_drl.py", "def broken(:\n")
    assert any(v.check == "parse" for v in lint.lint_project(p))


def test_pragma_without_reason_is_a_violation():
    p = PROJECT.overlay("src/repro/_seeded_pragma.py",
                        "x = 1  # lint: host-ok()\n")
    assert _hits(p, "pragma", "needs a justification")


def test_unknown_pragma_directive_is_a_violation():
    p = PROJECT.overlay("src/repro/_seeded_pragma.py",
                        "x = 1  # lint: hostok(typo'd directive)\n")
    assert _hits(p, "pragma", "unknown pragma directive")


def test_stale_pragma_is_a_violation():
    p = PROJECT.overlay("src/repro/_seeded_pragma.py",
                        "x = 1  # lint: host-ok(nothing here needs it)\n")
    assert _hits(p, "pragma", "stale pragma")


# ---------------------------------------------------------------------------
# seeded regressions: units & bounds
# ---------------------------------------------------------------------------

def test_unit_mismatched_add_trips_units():
    # kgCO2/kWh + W: adding a grid intensity to a power draw
    p = _seed("src/repro/dcsim/env.py",
              "de = env.carbon[:, tau] * dp / W_PER_KW",
              "de = (env.carbon[:, tau] + dp) / W_PER_KW")
    assert _hits(p, "units", "unit mismatch")


def test_undeclared_magic_factor_trips_units():
    p = _seed("src/repro/dcsim/env.py",
              "energy_cost = env.eprice[:, tau] * a * dp / W_PER_KW",
              "energy_cost = env.eprice[:, tau] * a * dp / 1000.0")
    assert _hits(p, "units", "magic scale factor")


def test_dropped_conversion_trips_suffix_contract():
    # dropping the W→kW conversion leaves carbon_kg carrying kgCO2·W/kWh
    p = _seed("src/repro/dcsim/env.py",
              "de = env.carbon[:, tau] * dp / W_PER_KW",
              "de = env.carbon[:, tau] * dp")
    assert _hits(p, "units", "`carbon_kg`")


def test_usd_suffix_key_carrying_kg_trips_units():
    p = _seed("src/repro/dcsim/env.py",
              '"sla_miss_cost_usd": jnp.sum(sla),',
              '"sla_miss_cost_usd": jnp.sum(de),')
    assert _hits(p, "units", "`sla_miss_cost_usd`")


def test_unit_table_drift_trips_units():
    p = _seed("src/repro/dcsim/env.py",
              "        eprice: USD/kWh\n", "")
    assert _hits(p, "units", "drifted")


def test_simplex_axis_flip_trips_bounds():
    p = _seed("src/repro/faults/failover.py",
              "w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), _EPS)",
              "w = w / jnp.maximum(jnp.sum(w, axis=0, keepdims=True), _EPS)")
    assert _hits(p, "bounds", "axis")


def test_unguarded_division_trips_bounds():
    p = _seed("src/repro/dcsim/env.py",
              "frac = ar / jnp.maximum(capacity_at(env, tau), 1e-9)",
              "frac = ar / capacity_at(env, tau)")
    assert _hits(p, "bounds", "not provably positive")


def test_stale_unit_ok_pragma_is_a_violation():
    p = PROJECT.overlay("src/repro/_seeded_pragma.py",
                        "x = 1  # lint: unit-ok(nothing here needs it)\n")
    assert _hits(p, "pragma", "stale pragma")


# ---------------------------------------------------------------------------
# compile-key behavior of the live spec (what the static checker guards)
# ---------------------------------------------------------------------------

STATIC_FORKS = [
    ("technique", "nash"),
    ("objective", "cost"),
    ("engine", "batched"),
    ("hours", 12),
    ("cfg", FDConfig(iters=41)),
    ("routed", True),
    ("guard", True),
    ("workload", "llm-mix"),
    ("taps", ("engine/hour",)),
]


@pytest.mark.parametrize("field,value", STATIC_FORKS,
                         ids=[f for f, _ in STATIC_FORKS])
def test_static_field_forks_engine_key(field, value):
    assert X._engine_key(SPEC.replace(**{field: value})) != X._engine_key(SPEC)


RUNTIME_ONLY = [
    ("seed", 7),
    ("seeds", (0, 1)),
    ("days", 3),
    ("pretrain", False),
]


@pytest.mark.parametrize("field,value", RUNTIME_ONLY,
                         ids=[f for f, _ in RUNTIME_ONLY])
def test_runtime_field_does_not_fork_engine_key(field, value):
    assert X._engine_key(SPEC.replace(**{field: value})) == X._engine_key(SPEC)


def test_failover_forks_only_when_faulted():
    alt = SPEC.replace(failover="spill_nearest")
    assert X._engine_key(alt) == X._engine_key(SPEC)
    assert (X._engine_key(alt, faulted=True)
            != X._engine_key(SPEC, faulted=True))


# ---------------------------------------------------------------------------
# runtime sanitizer: exact compile counts per engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,envs", [
    (SPEC.replace(hours=3), ENV),
    (SPEC.replace(hours=3, engine="batched", seeds=(0, 1)), [ENV, ENV]),
    (SPEC.replace(hours=3, engine="month", days=2), ENV),
], ids=["scan", "batched", "month"])
def test_engine_compiles_once_then_only_hits(spec, envs):
    X._clear_compile_caches()
    with lint.expect_compiles(1):
        first = run(spec, envs)
    with lint.expect_compiles(0):
        again = run(spec, envs)
    assert first["totals"].keys() == again["totals"].keys()
    tc = lint.trace_count(spec)
    if tc is not None:   # probe availability depends on the jax version
        assert tc == 1, f"intra-key retrace: jit traced {tc} programs"


def test_expect_compiles_fixture_names_the_forking_key(expect_compiles):
    X._clear_compile_caches()
    with pytest.raises(AssertionError, match="keys that missed"):
        with expect_compiles(0):
            run(SPEC.replace(hours=2), ENV)


# ---------------------------------------------------------------------------
# runtime pytree validation
# ---------------------------------------------------------------------------

def test_validate_env_params_green():
    assert lint.validate(ENV) is ENV


def test_validate_flags_wrong_ndim():
    bad = ENV._replace(avail=jnp.ones((4,)))
    with pytest.raises(TypeError, match="avail"):
        lint.validate(bad)


def test_validate_flags_axis_contradiction():
    bad = ENV._replace(rtt=jnp.zeros((5, 5), jnp.float32))
    with pytest.raises(TypeError, match="contradicts"):
        lint.validate(bad)


def test_validate_flags_weak_typed_leaf():
    bad = ENV._replace(avail=jnp.full((4, 24), 1.0))   # no dtype: weak
    with pytest.raises(TypeError, match="weak-typed"):
        lint.validate(bad)


def test_validate_rejects_undeclared_class():
    with pytest.raises(TypeError, match="no pytree schema"):
        lint.validate(object())
