"""Per-source request routing: the (S, I, D) decision surface.

Two families of guarantees:

1. **Degenerate parity** — with the S = 1 aggregate origin, every routed
   engine (scan, loop, month, batched) and every solver reproduces the
   unrouted (PR 3) numbers *bit-for-bit*: the single source row is exactly
   the uniform-origin mean RTT the unrouted model prices, and all routed
   array math reduces to the same float ops.
2. **Routing is a real decision surface** — on a non-uniform ``origin_shift``
   env the routed game prices locality, the projection conserves per-source
   demand, and a routed solver beats the source-blind split on SLA cost.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios as S
from repro.core import gt_drl
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.core.game import GameContext, fractions_to_ar
from repro.core.nash import NashConfig
from repro.core.ppo import PPOConfig
from repro.dcsim import env as E

ENV = E.build_env(4, seed=0)
# SLA priced + WAN visible: the regime where routing decisions matter
SLA_ENV = S.make("wan_degradation", factor=3.0, extra_ms=30.0)(
    S.make("sla_tighten", tighten=0.6, price=1e-4)(ENV))
AGG = E.aggregate_origin(SLA_ENV)        # S = 1: the parity reference
SHIFTED = S.make("origin_shift", toward=[0], weight=0.8)(SLA_ENV)

FD_CFG = FDConfig(iters=40)
NASH_CFG = NashConfig(sweeps=2, inner_steps=15)
FAST_GTDRL = gt_drl.GTDRLConfig(
    ppo=PPOConfig(horizon=4, episodes=16, iters=2, update_epochs=2),
    rounds=1, polish_steps=10, pretrain_iters=2, pretrain_batch=2)

KEY = jax.random.PRNGKey(0)
PEAK = jnp.zeros((4,))


def _exact(a, b, label=""):
    assert a == b, (label, a, b)


# ---------------------------------------------------------------------------
# env-layer basics
# ---------------------------------------------------------------------------

def test_build_env_origin_is_uniform_over_dc_regions():
    o = np.asarray(ENV.origin)
    assert o.shape == (4, 10, 24)
    np.testing.assert_allclose(o, 0.25)
    np.testing.assert_allclose(o.sum(axis=0), 1.0)


def test_source_rtt_shapes_and_aggregate_row():
    assert E.source_rtt(SLA_ENV).shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(E.source_rtt(SLA_ENV)),
                                  np.asarray(SLA_ENV.rtt))
    agg = np.asarray(E.source_rtt(AGG))
    assert agg.shape == (1, 4)
    np.testing.assert_array_equal(agg[0],
                                  np.asarray(jnp.mean(SLA_ENV.rtt, axis=0)))
    bad = SLA_ENV._replace(origin=jnp.ones((3, 10, 24)) / 3.0)
    with pytest.raises(ValueError):
        E.source_rtt(bad)


def test_access_ms_rejects_legacy_vector_rtt():
    from repro.dcsim import latency as L
    with pytest.raises(ValueError):
        L.access_ms(jnp.zeros((4,)))


def test_project_feasible_routed_conserves_per_source_demand():
    """Σ_d AR3[s, i, d] == car[i] · origin[s, i] wherever the fleet has
    headroom, totals obey capacity, and nothing is negative."""
    env, tau = SHIFTED, 18
    f = jax.random.dirichlet(KEY, jnp.ones((4, 10, 4)))
    ar3 = E.project_feasible_routed(env, f, tau)
    assert bool(jnp.all(ar3 >= 0))
    tot = jnp.sum(ar3, axis=0)
    er_t = E.capacity_at(env, tau)
    assert bool(jnp.all(tot <= er_t * (1 + 1e-5)))
    demand = env.car[:, tau][None, :] * E.origin_at(env, tau)
    np.testing.assert_allclose(np.asarray(jnp.sum(ar3, axis=2)),
                               np.asarray(demand), rtol=2e-3)


def test_routed_latency_prices_paths_not_the_mean():
    """On the shifted env a nearby path must be cheaper than a cross-country
    one, and the unrouted latency is the uniform-source mean of the routed."""
    tau = 18
    ar = E.project_feasible(SLA_ENV, jnp.full((10, 4), 0.25), tau)
    lat3 = E.latency_ms_routed(SLA_ENV, ar, tau)   # (S, I, D)
    lat2 = E.latency_ms(SLA_ENV, ar, tau)          # (I, D) fleet-mean access
    np.testing.assert_allclose(np.asarray(lat3.mean(axis=0)),
                               np.asarray(lat2), rtol=1e-5)
    # serving NY-origin traffic in NY (s=0, d=0) beats hauling it to SF (d=1)
    assert float(lat3[0, 0, 0]) < float(lat3[0, 0, 1])


# (routed Σ-estimator == simulator reconciliation lives with the other
# estimator identities: test_consistency.test_routed_sla_estimator_...)


# ---------------------------------------------------------------------------
# degenerate S = 1 parity: engines
# ---------------------------------------------------------------------------

TOTAL_KEYS = ("carbon_kg", "cost_usd", "sla_miss_cost_usd", "violation")


@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_day_engines_routed_s1_match_unrouted_bitwise(engine):
    kw = dict(seed=0, hours=6, cfg_override=FD_CFG, engine=engine)
    un = SCH.run_day(AGG, "fd", "cost_sla", **kw)
    ro = SCH.run_day(AGG, "fd", "cost_sla", routed=True, **kw)
    for k in TOTAL_KEYS:
        _exact(un["totals"][k], ro["totals"][k], (engine, k))
    for a, b in zip(un["per_epoch"], ro["per_epoch"]):
        _exact(a["latency_ms"], b["latency_ms"], (engine, "latency_ms"))


def test_nash_scan_routed_s1_matches_unrouted_bitwise():
    kw = dict(seed=0, hours=4, cfg_override=NASH_CFG)
    un = SCH.run_day(AGG, "nash", "cost_sla", **kw)
    ro = SCH.run_day(AGG, "nash", "cost_sla", routed=True, **kw)
    for k in TOTAL_KEYS:
        _exact(un["totals"][k], ro["totals"][k], k)


def test_month_routed_s1_matches_unrouted_bitwise():
    kw = dict(days=2, hours=4, cfg_override=FD_CFG)
    un = SCH.run_month(AGG, "fd", "cost_sla", **kw)
    ro = SCH.run_month(AGG, "fd", "cost_sla", routed=True, **kw)
    for k in TOTAL_KEYS:
        np.testing.assert_array_equal(un["day_totals"][k], ro["day_totals"][k])
    np.testing.assert_array_equal(un["peak_w"], ro["peak_w"])


def test_batched_routed_s1_matches_unrouted_bitwise():
    envs = [AGG, E.aggregate_origin(S.make("flash_crowd")(SLA_ENV))]
    kw = dict(hours=4, cfg_override=FD_CFG, seeds=[0, 1])
    un = SCH.run_days_batched(envs, "fd", "cost_sla", **kw)
    ro = SCH.run_days_batched(envs, "fd", "cost_sla", routed=True, **kw)
    for k in TOTAL_KEYS:
        np.testing.assert_array_equal(un["totals"][k], ro["totals"][k])


def test_compare_techniques_routed_s1_matches_unrouted():
    kw = dict(objective="cost_sla", hours=3, seed0=0,
              cfg_overrides={"fd": FD_CFG})
    un = SCH.compare_techniques([AGG], ("fd",), **kw)
    ro = SCH.compare_techniques([AGG], ("fd",), routed=True, **kw)
    _exact(un["fd"]["mean"], ro["fd"]["mean"])


# ---------------------------------------------------------------------------
# degenerate S = 1 parity: every solver's epoch solve
# ---------------------------------------------------------------------------

def _solver_fractions(technique, ctx, cfg):
    if technique == "gt-drl":
        agents = gt_drl.init_agents(KEY, ctx.env, cfg, ctx.routed)
        _, res = gt_drl.solve_epoch(KEY, agents, ctx, PEAK, cfg)
        return res.fractions
    mod, _ = SCH._MODS[technique]
    return mod.solve_epoch(KEY, ctx, PEAK, cfg=cfg).fractions


@pytest.mark.parametrize("technique,cfg", [
    ("fd", FD_CFG),
    ("nash", NASH_CFG),
    ("ga", dataclasses.replace(SCH._MODS["ga"][1], generations=30)),
    ("ddpg", dataclasses.replace(SCH._MODS["ddpg"][1], steps=40)),
    ("ppo", SCH._MODS["ppo"][1].__class__(
        ppo=PPOConfig(horizon=4, episodes=16, iters=2, update_epochs=2))),
    ("gt-drl", FAST_GTDRL),
])
def test_solver_routed_s1_fractions_match_unrouted_bitwise(technique, cfg):
    """With the S = 1 aggregate origin there is nothing to route, so every
    technique's routed solve IS the unrouted program (GameContext.is_routed
    normalizes the degenerate axis away): identical shape, identical bits."""
    tau = jnp.int32(18)
    un = _solver_fractions(technique, GameContext(
        env=AGG, tau=tau, objective="cost_sla"), cfg)
    ro = _solver_fractions(technique, GameContext(
        env=AGG, tau=tau, objective="cost_sla", routed=True), cfg)
    assert ro.shape == un.shape
    np.testing.assert_array_equal(np.asarray(ro), np.asarray(un))


def test_env_layer_generic_s1_path_is_bitwise():
    """The generic (1, I, D) routed math itself — not just the normalized
    program — reproduces the unrouted bills bit-for-bit: per-path pricing
    over a single aggregate source at the mean RTT is the PR 3 model."""
    tau = 18
    key = jax.random.PRNGKey(9)
    f = jax.random.uniform(key, (10, 4), minval=0.05, maxval=1.0)
    f = f / f.sum(axis=1, keepdims=True)
    ar = E.project_feasible(AGG, f, tau)
    ar3 = E.project_feasible_routed(AGG, f[None], tau)
    np.testing.assert_array_equal(np.asarray(ar3[0]), np.asarray(ar))
    _, m2 = E.step_epoch(AGG, PEAK, ar, tau)
    _, m3 = E.step_epoch(AGG, PEAK, ar3, tau)
    for k in m2:
        _exact(float(m2[k]), float(m3[k]), k)
    np.testing.assert_array_equal(
        np.asarray(E.player_reward(AGG, ar, tau, PEAK, "cost_sla")),
        np.asarray(E.player_reward(AGG, ar3, tau, PEAK, "cost_sla")))


# ---------------------------------------------------------------------------
# routing as a decision surface: beating the source-blind split
# ---------------------------------------------------------------------------

def test_routed_fd_beats_source_blind_on_shifted_origins():
    """With origins massed on NY and the WAN degraded, optimizing the
    (S, I, D) tensor must cut the SLA bill vs broadcasting the unrouted
    (I, D) split to every source (the PR 3 decision surface priced under
    the routed simulator)."""
    tau = jnp.int32(18)
    ctx_r = GameContext(env=SHIFTED, tau=tau, objective="cost_sla", routed=True)
    ctx_u = GameContext(env=SHIFTED, tau=tau, objective="cost_sla")
    from repro.core import force_directed as FD
    routed = FD.solve_epoch(KEY, ctx_r, PEAK, cfg=FDConfig(iters=120)).fractions
    blind2 = FD.solve_epoch(KEY, ctx_u, PEAK, cfg=FDConfig(iters=120)).fractions
    blind = jnp.broadcast_to(blind2, (4,) + blind2.shape)
    sla_routed = float(jnp.sum(E.sla_cost_routed(
        SHIFTED, fractions_to_ar(ctx_r, routed), tau)))
    sla_blind = float(jnp.sum(E.sla_cost_routed(
        SHIFTED, fractions_to_ar(ctx_r, blind), tau)))
    assert sla_routed < 0.9 * sla_blind, (sla_routed, sla_blind)
    # and the routed objective (cost + SLA) improves too, not just latency
    from repro.core.game import cloud_objective
    assert float(cloud_objective(ctx_r, routed, PEAK)) < float(
        cloud_objective(ctx_r, blind, PEAK))


def test_routing_suite_builds_and_runs_batched():
    rows = S.build_suite("routing", ENV)
    names = [n for n, _ in rows]
    assert "east-business-day" in names and "uniform-origin" in names
    envs = [e for _, e in rows]
    res = SCH.run_days_batched(envs, "fd", "cost_sla", hours=3,
                               cfg_override=FD_CFG, routed=True)
    assert res["totals"]["cost_usd"].shape == (len(rows),)
    assert np.all(np.isfinite(res["totals"]["cost_usd"]))
    assert np.all(res["totals"]["sla_miss_cost_usd"] > 0)


def test_origin_transforms_keep_origin_normalized():
    for env in (S.make("origin_shift", toward=[1, 3], weight=0.6,
                       start=4, duration=8)(ENV),
                S.make("flash_crowd", sources=[2])(ENV),
                S.make("flash_crowd", sources=[0, 1], tasks=[3])(ENV),
                # a regional *dip* must clamp, not drain a source negative
                S.make("flash_crowd", magnitude=0.5, sources=[0])(ENV)):
        o = np.asarray(env.origin)
        assert o.shape == np.asarray(ENV.origin).shape
        assert o.min() >= 0.0
        np.testing.assert_allclose(o.sum(axis=0), 1.0, rtol=1e-6)


def test_gtdrl_routed_env_state_mode_runs():
    """state_mode='env' gains the origin-weighted RTT feature when routed."""
    cfg = dataclasses.replace(FAST_GTDRL, state_mode="env")
    d = E.num_dcs(SHIFTED)
    assert gt_drl.state_dim(SHIFTED, "env", routed=True) == 4 * d + 6 * d
    assert gt_drl.state_dim(SHIFTED, "env", routed=False) == d + 5 * d
    ctx = GameContext(env=SHIFTED, tau=jnp.int32(12), objective="cost_sla",
                      routed=True)
    agents = gt_drl.init_agents(KEY, SHIFTED, cfg, routed=True)
    _, res = gt_drl.solve_epoch(KEY, agents, ctx, PEAK, cfg)
    assert res.fractions.shape == (4, 10, 4)
    assert bool(jnp.all(jnp.isfinite(res.fractions)))
