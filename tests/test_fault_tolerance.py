"""Fault tolerance: supervisor restart loop, straggler detection, elastic
restore, end-to-end train-loop crash/resume."""

import jax
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault_tolerance import (FailurePolicy, HeartbeatMonitor,
                                               SimulatedFailure, run_with_retries)
from repro.launch.train import train_loop


def test_straggler_detection():
    mon = HeartbeatMonitor(num_workers=4, window=8)
    for step in range(8):
        for w in range(4):
            mon.record(w, 1.0 if w != 2 else 2.5)
    s = mon.stragglers()
    assert len(s) == 1 and s[0].worker == 2 and s[0].ratio > 2.0


def test_dead_worker_detection():
    mon = HeartbeatMonitor(num_workers=3, dead_after_s=10.0)
    now = 1000.0
    for w in range(3):
        mon.record(w, 1.0, now=now)
    mon.record(0, 1.0, now=now + 20)
    mon.record(1, 1.0, now=now + 20)
    assert mon.dead(now=now + 20) == [2]


def test_failure_policy():
    pol = FailurePolicy(elastic=True)
    assert pol.decide([], 0) == "continue"
    assert pol.decide([3], 2) == "replace"
    assert pol.decide([3, 4, 5], 1) == "shrink"
    assert FailurePolicy(elastic=False).decide([3, 4], 0) == "restart"


def test_supervisor_restarts_to_completion():
    log = {"completed": [], "saved_at": 0}

    def step_fn(step):
        if step == 7 and log["restarted"] == 0:
            log["restarted"] += 1
            raise SimulatedFailure()
        log["completed"].append(step)

    log["restarted"] = 0
    events = run_with_retries(
        step_fn, total_steps=10, save_every=5,
        save_fn=lambda s: log.__setitem__("saved_at", s),
        restore_fn=lambda: log["saved_at"],
    )
    assert events["restarts"] == 1
    assert max(log["completed"]) == 9
    # steps 5 and 6 replayed after restore from 5
    assert log["completed"].count(5) == 2 and log["completed"].count(6) == 2


def test_train_loop_crash_resume_identical(tmp_path):
    """Full driver: run 30 steps; run again with a crash at 17 + resume; the
    final losses must match exactly (deterministic pipeline + checkpoint)."""
    kw = dict(arch="llama3.2-1b", smoke=True, batch=2, seq=32, lr=1e-3,
              seed=3, save_every=10, log_every=1000)
    ref = train_loop(steps=30, ckpt_dir=str(tmp_path / "a"), **kw)

    with pytest.raises(RuntimeError, match="simulated failure"):
        train_loop(steps=30, ckpt_dir=str(tmp_path / "b"), fail_at=17, **kw)
    resumed = train_loop(steps=30, ckpt_dir=str(tmp_path / "b"), **kw)
    assert resumed["losses"][-1] == ref["losses"][-1]


def test_elastic_restore_shape_agnostic(tmp_path):
    """A checkpoint restores into templates regardless of sharding origin —
    the CPU analogue of restoring a 256-chip checkpoint on 512 chips."""
    from repro.configs import get_config
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import init_train_state

    cfg = get_config("llama3.2-1b").smoke()
    state = init_train_state(jax.random.PRNGKey(0), cfg, AdamWConfig())
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": state.params})
    # restore with device_put to an explicit (trivial) sharding
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    from repro.distributed.sharding import param_shardings
    sh = param_shardings(state.params, mesh)
    _, restored, _ = mgr.restore_latest(
        {"params": jax.eval_shape(lambda: state.params)},
        shardings={"params": sh})
    leaf = jax.tree_util.tree_leaves(restored["params"])[0]
    assert leaf.sharding is not None
