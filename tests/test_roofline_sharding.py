"""Roofline extraction machinery + sharding rule unit tests."""
from types import SimpleNamespace

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch import roofline
from repro.distributed import sharding as shd


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
ENTRY %main (p0: bf16[256,1024]) -> bf16[256,1024] {
  %p0 = bf16[256,1024]{1,0} parameter(0)
  %ag = bf16[256,16384]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[128,64]{1,0} all-reduce(%conv), to_apply=%sum
  %rs = bf16[16,1024]{1,0} reduce-scatter(%ag), dimensions={0}
  %cp = u32[8]{0} collective-permute(%idx), source_target_pairs={{0,1}}
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%x, %y), dimensions={0}
  %dot = f32[128,64]{1,0} dot(%a, %b)
}
"""


def test_collective_bytes_parser():
    total, detail = roofline.collective_bytes(HLO_SAMPLE)
    expect = (
        256 * 16384 * 2      # all-gather bf16
        + 128 * 64 * 4       # all-reduce f32
        + 16 * 1024 * 2      # reduce-scatter bf16
        + 8 * 4              # collective-permute u32
        + 2 * 4 * 4 * 4      # all-to-all tuple of two f32[4,4]
    )
    assert total == expect
    assert detail["counts"]["all-gather"] == 1
    assert detail["counts"]["all-to-all"] == 1


def test_probe_extrapolation_linear():
    # cost(L) = 10 + 3L  -> probes at L=2 (16) and L=4 (22) -> L=10: 40
    c1 = (16.0, 16.0, 16.0)
    c2 = (22.0, 22.0, 22.0)
    out = roofline.probe_extrapolate(c1, c2, period=2, num_layers=10)
    assert out == (40.0, 40.0, 40.0)


def test_model_flops_train_vs_decode():
    cfg = SimpleNamespace(num_experts=0)
    shape_t = SimpleNamespace(global_batch=8, seq_len=128, kind="train")
    shape_d = SimpleNamespace(global_batch=8, seq_len=128, kind="decode")
    n = 1_000_000
    assert roofline.model_flops(cfg, shape_t, n) == 6.0 * n * 8 * 128
    assert roofline.model_flops(cfg, shape_d, n) == 2.0 * n * 8


# ---------------------------------------------------------------------------
# sharding rules (mesh sizes faked; only axis sizes are consulted)
# ---------------------------------------------------------------------------

class FakeMesh:
    axis_names = ("data", "model")
    devices = np.zeros((16, 16))


def test_param_rules_divisibility():
    mesh = FakeMesh()
    # qwen2-7b style q-proj: 3584 -> 28*128; flattened dims divide 16;
    # scan-stacked params carry the (L, ...) depth dim
    spec = shd._param_spec(mesh, "stack/layers/attn/wq/w", (28, 3584, 3584), False)
    assert spec == P(None, None, "model")  # stacked: leading depth dim
    spec = shd._param_spec(mesh, "stack/blocks/0/attn/wq/w", (3584, 3584), False)
    assert spec == P(None, "model")
    # whisper vocab 51865 does NOT divide 16 -> replicated dim
    spec = shd._param_spec(mesh, "embed/w", (51865, 512), False)
    assert spec == P(None, "model")
    # arctic stacked experts (L, 128, d, f): expert-parallel over model
    spec = shd._param_spec(mesh, "stack/layers/moe/experts/w_in/w", (35, 128, 7168, 4864), False)
    assert spec == P(None, "model", None, None)
    # qwen2-moe 60 experts do not divide 16 -> shard ffn width instead
    spec = shd._param_spec(mesh, "stack/blocks/0/moe/experts/w_in/w", (60, 2048, 1408), False)
    assert spec == P(None, None, "model")
    # fsdp adds data-axis sharding on the other dim
    spec = shd._param_spec(mesh, "stack/blocks/0/mlp/w_in/w", (4096, 11008), True)
    assert spec == P("data", "model")
    # norms replicate
    spec = shd._param_spec(mesh, "stack/blocks/0/norm1/scale", (4096,), False)
    assert spec == P(None)


def test_cache_rules():
    mesh = FakeMesh()
    # stacked KV cache (L, B, S, KVH, hd): batch over data, seq over model
    spec = shd._cache_spec(mesh, "k", (16, 128, 32768, 8, 64), batch=128)
    assert spec == P(None, "data", "model", None, None)
    # batch=1 long-context: no batch sharding
    spec = shd._cache_spec(mesh, "h", (1, 4096), batch=1)
    assert spec == P(None, "model")


def test_batch_spec_drops_pod_when_indivisible():
    class M3:
        axis_names = ("pod", "data", "model")
        devices = np.zeros((2, 16, 16))

    # 256 % 32 == 0: shard over (pod, data)
    assert shd.batch_spec(M3(), 256) == P(("pod", "data"))
    # batch=16 % 32 != 0 but % 16 == 0: drop pod, keep data
    assert shd.batch_spec(M3(), 16) == P(("data",))
    # batch=1: fully replicated
    assert shd.batch_spec(M3(), 1) == P(None)
