"""Hypothesis compatibility layer.

When hypothesis is installed (requirements-dev.txt) the real ``given`` /
``strategies`` are re-exported and nothing changes. When it is absent the
property tests still run: a tiny deterministic sampler draws a handful of
seeded examples per test instead of hypothesis' shrinking search. Coverage
is thinner but the invariants are still exercised, and collection never
fails on the missing import.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import random

    _EXAMPLES = 5  # deterministic draws per test

    def settings(*args, **kwargs):  # noqa: D103 - decorator-factory no-op
        if args and callable(args[0]):  # bare @settings
            return args[0]
        return lambda fn: fn

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # callable(rng) -> value

    class st:  # minimal strategies stand-in
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            def sample(rng):
                n = rng.randint(min_size, max_size if max_size is not None else min_size + 4)
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                def sample(rng):
                    return fn(lambda s: s.sample(rng), *args, **kwargs)
                return _Strategy(sample)
            return make

    def given(*strategies):
        def deco(fn):
            def runner():
                rng = random.Random(0xC0FFEE)
                for _ in range(_EXAMPLES):
                    fn(*[s.sample(rng) for s in strategies])
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco
