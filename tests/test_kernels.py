"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes, plus the flash custom_vjp gradients."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_vjp import flash_attention_jnp
from repro.kernels.ref import attention_chunked, attention_ref, decode_attention_ref

KEY = jax.random.PRNGKey(0)


def _qkv(b, sq, skv, h, kvh, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, kvh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, kvh, d), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # b, s, h, kvh, d, causal, window
    (1, 128, 4, 4, 64, True, 0),
    (2, 256, 4, 2, 64, True, 0),
    (2, 256, 8, 1, 32, True, 0),       # MQA
    (1, 384, 4, 2, 128, True, 64),     # sliding window
    (1, 128, 2, 2, 64, False, 0),      # non-causal (encoder/cross)
    (2, 192, 6, 3, 32, True, 0),       # non-pow2 seq, odd group
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    b, s, h, kvh, d, causal, window = case
    q, k, v = _qkv(b, s, s, h, kvh, d, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert out.shape == ref.shape
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


def test_flash_attention_softcap():
    q, k, v = _qkv(1, 128, 128, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=30.0,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, softcap=30.0)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


DECODE_CASES = [
    (2, 512, 8, 2, 64),
    (1, 300, 4, 1, 32),    # ragged length, MQA
    (3, 1024, 4, 4, 128),
    (2, 257, 14, 2, 64),   # non-pow2, group 7 (qwen2-like)
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(case, dtype):
    b, s, h, kvh, d = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32).astype(dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, kc, vc, lens, block_k=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("case", [(2, 512, 4, 2, 64, True, 0), (1, 384, 4, 1, 32, True, 128)])
def test_chunked_streaming_matches_ref(case):
    b, s, h, kvh, d, causal, window = case
    q, k, v = _qkv(b, s, s, h, kvh, d, jnp.float32)
    o1 = attention_ref(q, k, v, causal=causal, window=window)
    o2 = attention_chunked(q, k, v, causal=causal, window=window, chunk=128)
    o3 = flash_attention_jnp(q, k, v, causal=causal, window=window, chunk=128)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5
    assert float(jnp.max(jnp.abs(o1 - o3))) < 1e-5


@pytest.mark.parametrize("softcap", [0.0, 25.0])
def test_flash_vjp_gradients(softcap):
    q, k, v = _qkv(1, 256, 256, 4, 2, 32, jnp.float32)

    def loss(fn):
        return lambda q_, k_, v_: jnp.sum(fn(q_, k_, v_) ** 2)

    ref_fn = lambda *a: attention_ref(*a, causal=True, softcap=softcap)
    new_fn = lambda *a: flash_attention_jnp(*a, causal=True, softcap=softcap, chunk=64)
    g_ref = jax.grad(loss(ref_fn), argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(loss(new_fn), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_new):
        assert float(jnp.max(jnp.abs(a - b_))) < 5e-3


def test_decode_matches_last_row_of_prefill():
    """decode(q_last) over a filled cache == last row of full attention."""
    b, s, h, kvh, d = 2, 256, 4, 2, 64
    q, k, v = _qkv(b, s, s, h, kvh, d, jnp.float32)
    full = attention_ref(q, k, v, causal=True)
    lens = jnp.full((b,), s, jnp.int32)
    dec = decode_attention_ref(q[:, -1], k, v, lens)
    assert float(jnp.max(jnp.abs(full[:, -1] - dec))) < 1e-5
