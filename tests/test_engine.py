"""Compiled evaluation pipeline: GT-DRL half-compute rounds (gather vs the
masked reference, dispatch counting), deploy-once scan-vs-loop parity,
batched ``compare_techniques`` vs the loop reference, ``run_month`` day-0
agreement and monotone monthly peaks, and zero-denominator state guards."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios as S
from repro.core import gt_drl
from repro.core import schedulers as SCH
from repro.core.force_directed import FDConfig
from repro.core.game import GameContext, uniform_fractions
from repro.core.nash import NashConfig
from repro.core.ppo import PPOConfig
from repro.dcsim import env as E

ENV = E.build_env(4, seed=0)
PEAK = jnp.zeros((4,))
KEY = jax.random.PRNGKey(0)
CTX = GameContext(env=ENV, tau=jnp.int32(18), objective="carbon")

FAST_GTDRL = gt_drl.GTDRLConfig(
    ppo=PPOConfig(horizon=4, episodes=16, iters=2, update_epochs=2),
    rounds=2, polish_steps=15, pretrain_iters=4, pretrain_batch=2)
FD_CFG = FDConfig(iters=60)
NASH_CFG = NashConfig(sweeps=3, inner_steps=20)


# ---------------------------------------------------------------------------
# GT-DRL red-black half-update: gathered I/2 dispatch
# ---------------------------------------------------------------------------

def test_half_update_gather_matches_masked_reference():
    """Gathering the active parity then scattering back must reproduce the
    full-width masked implementation exactly (identical per-player keys)."""
    agents = gt_drl.init_agents(KEY, ENV, FAST_GTDRL)
    masked_cfg = dataclasses.replace(FAST_GTDRL, half_update="masked")
    a_g, r_g = gt_drl.solve_epoch(KEY, agents, CTX, PEAK, FAST_GTDRL)
    a_m, r_m = gt_drl.solve_epoch(KEY, agents, CTX, PEAK, masked_cfg)
    np.testing.assert_allclose(np.asarray(r_g.fractions),
                               np.asarray(r_m.fractions), rtol=1e-5, atol=1e-7)
    for lg, lm in zip(jax.tree_util.tree_leaves(a_g),
                      jax.tree_util.tree_leaves(a_m)):
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lm),
                                   rtol=1e-5, atol=1e-7)


def test_half_update_rejects_unknown_impl():
    cfg = dataclasses.replace(FAST_GTDRL, half_update="jacobi")
    agents = gt_drl.init_agents(KEY, ENV, FAST_GTDRL)
    with pytest.raises(ValueError):
        gt_drl.solve_epoch(KEY, agents, CTX, PEAK, cfg)


def test_half_update_dispatches_half_the_players(monkeypatch):
    """The gathered impl pays _one_player_round for I/2 players per half —
    I per round — where the masked reference pays 2I. Count the actual
    per-player dispatches with a debug callback (one call per vmap lane)."""
    i_n = E.num_players(ENV)
    calls = []
    orig = gt_drl._one_player_round

    def counting(key, agent, *args, i, **kw):
        jax.debug.callback(lambda ii: calls.append(int(ii)), i)
        return orig(key, agent, *args, i=i, **kw)

    monkeypatch.setattr(gt_drl, "_one_player_round", counting)
    cfg = dataclasses.replace(FAST_GTDRL, rounds=1)
    agents = gt_drl.init_agents(KEY, ENV, cfg)

    jax.block_until_ready(gt_drl.solve_epoch(KEY, agents, CTX, PEAK, cfg))
    jax.effects_barrier()
    assert len(calls) == i_n            # I/2 red + I/2 black, not 2I
    assert sorted(calls) == list(range(i_n))  # every player responded once

    calls.clear()
    jax.block_until_ready(gt_drl.solve_epoch(
        KEY, agents, CTX, PEAK, dataclasses.replace(cfg, half_update="masked")))
    jax.effects_barrier()
    assert len(calls) == 2 * i_n        # the reference pays full width twice


def test_batched_pretrain_is_finite_and_improves():
    agents = gt_drl.pretrain(KEY, ENV, "carbon", FAST_GTDRL)
    for leaf in jax.tree_util.tree_leaves(agents):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    _, res = gt_drl.solve_epoch(KEY, agents, CTX, PEAK, FAST_GTDRL)
    from repro.core.game import cloud_objective
    v = float(cloud_objective(CTX, res.fractions, PEAK))
    assert v < float(cloud_objective(CTX, uniform_fractions(CTX), PEAK))


# ---------------------------------------------------------------------------
# zero-denominator guards for state_mode="env"
# ---------------------------------------------------------------------------

def test_ctx_features_finite_under_zero_fields():
    """Zero-carbon grid / dead renewables / free power must not NaN the
    state features (renewable_drought scale=0 and friends hit this)."""
    dead = ENV._replace(carbon=jnp.zeros_like(ENV.carbon),
                        eprice=jnp.zeros_like(ENV.eprice),
                        rp=jnp.zeros_like(ENV.rp))
    f = gt_drl._ctx_features(dead, jnp.int32(3), 0)
    assert bool(jnp.all(jnp.isfinite(f)))


def test_env_state_mode_finite_under_renewable_drought():
    env = S.make("renewable_drought", scale=0.0)(ENV)._replace(
        carbon=jnp.zeros_like(ENV.carbon))
    cfg = dataclasses.replace(FAST_GTDRL, state_mode="env", rounds=1)
    agents = gt_drl.init_agents(KEY, env, cfg)
    ctx = GameContext(env=env, tau=jnp.int32(12), objective="carbon")
    _, res = gt_drl.solve_epoch(KEY, agents, ctx, PEAK, cfg)
    assert bool(jnp.all(jnp.isfinite(res.fractions)))


# ---------------------------------------------------------------------------
# deploy-once GT-DRL: scan engine vs the loop reference
# ---------------------------------------------------------------------------

def test_gtdrl_deploy_once_scan_matches_loop():
    agents0 = gt_drl.init_agents(jax.random.PRNGKey(7), ENV, FAST_GTDRL)
    sched = SCH.GTDRLScheduler(ENV, "carbon", FAST_GTDRL, agents=agents0)
    loop = SCH.run_day(ENV, "gt-drl", seed=0, hours=4,
                       solver=sched.solve_epoch, engine="loop")
    scan = SCH.run_day(ENV, "gt-drl", seed=0, hours=4, engine="scan",
                       cfg_override=FAST_GTDRL, solver_state0=agents0)
    for k in ("carbon_kg", "cost_usd", "violation"):
        a, b = loop["totals"][k], scan["totals"][k]
        assert abs(a - b) <= 1e-4 * max(abs(a), 1.0), (k, a, b)


# ---------------------------------------------------------------------------
# batched compare_techniques vs the loop reference
# ---------------------------------------------------------------------------

def test_compare_techniques_batched_matches_loop():
    suite = S.build_suite("baseline", ENV)
    envs = [e for _, e in suite][:3]
    kw = dict(objective="carbon", hours=6, seed0=0,
              cfg_overrides={"fd": FD_CFG, "nash": NASH_CFG})
    loop = SCH.compare_techniques(envs, ("fd", "nash"), engine="loop", **kw)
    bat = SCH.compare_techniques(envs, ("fd", "nash"), engine="batched", **kw)
    for t in ("fd", "nash"):
        np.testing.assert_allclose(bat[t]["mean"], loop[t]["mean"], rtol=1e-4)
        np.testing.assert_allclose(bat[t]["stderr"], loop[t]["stderr"],
                                   rtol=1e-3, atol=1e-6)
        np.testing.assert_allclose(bat[t]["curve_mean"], loop[t]["curve_mean"],
                                   rtol=1e-3)


def test_compare_techniques_gtdrl_deploy_once_batched_matches_loop():
    envs = [ENV, S.Scenario("arrival_resample", {"seed": 1}).apply(ENV)]
    kw = dict(objective="carbon", hours=3, seed0=0,
              cfg_overrides={"gt-drl": FAST_GTDRL})
    loop = SCH.compare_techniques(envs, ("gt-drl",), engine="loop", **kw)
    bat = SCH.compare_techniques(envs, ("gt-drl",), engine="batched", **kw)
    np.testing.assert_allclose(bat["gt-drl"]["mean"], loop["gt-drl"]["mean"],
                               rtol=1e-4)
    np.testing.assert_allclose(bat["gt-drl"]["curve_mean"],
                               loop["gt-drl"]["curve_mean"], rtol=1e-3)


def test_compare_techniques_rejects_unknown_engine():
    with pytest.raises(ValueError):
        SCH.compare_techniques([ENV], ("fd",), engine="Batched")


# ---------------------------------------------------------------------------
# run_month: day-0 parity, monotone peaks, agent threading
# ---------------------------------------------------------------------------

def test_run_month_day0_matches_run_day():
    m = SCH.run_month(ENV, "fd", days=3, seed=0, hours=24, cfg_override=FD_CFG)
    d0 = SCH.run_day(ENV, "fd", seed=0, hours=24, cfg_override=FD_CFG)
    np.testing.assert_allclose(m["day_totals"]["carbon_kg"][0],
                               d0["totals"]["carbon_kg"], rtol=1e-5)
    np.testing.assert_allclose(m["per_day"]["cost_usd"][0],
                               [e["cost_usd"] for e in d0["per_epoch"]],
                               rtol=1e-4)


def test_run_month_peak_state_is_monotone_and_charged_once():
    month = S.build_month(ENV, days=5, seed=0)
    res = SCH.run_month(month, "fd", cfg_override=FD_CFG)  # (name, env) rows ok
    peaks = res["peak_w"]  # (days, D) end-of-day monthly peaks
    assert peaks.shape == (5, 4)
    assert np.all(np.diff(peaks, axis=0) >= -1e-5)  # never decreases
    np.testing.assert_allclose(peaks[-1], res["final_peak_w"], rtol=1e-6)
    # once the monthly peak is established, later days stop paying for it:
    # day 0 (which sets most of the peak) bears a strictly larger peak charge
    peak_cost = res["per_day"]["peak_cost_usd"].sum(axis=1)
    assert peak_cost[0] > peak_cost[1:].max()


def test_run_month_shapes_and_total_consistency():
    res = SCH.run_month(ENV, "fd", days=2, hours=24, cfg_override=FD_CFG)
    assert res["days"] == 2
    assert res["per_day"]["carbon_kg"].shape == (2, 24)
    np.testing.assert_allclose(
        res["totals"]["carbon_kg"],
        res["day_totals"]["carbon_kg"].sum(), rtol=1e-6)
    with pytest.raises(ValueError):
        SCH.run_month([ENV, ENV], "fd", days=3)


def test_stack_and_tile_env_helpers():
    st = E.stack_envs([ENV, ENV])
    assert st.er.shape == (2,) + ENV.er.shape
    ti = E.tile_env(ENV, 3)
    assert ti.car.shape == (3,) + ENV.car.shape
    np.testing.assert_array_equal(np.asarray(ti.car[1]), np.asarray(ENV.car))
