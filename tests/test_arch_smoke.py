"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced config of the same family, runs a forward and a train step on CPU
with correct shapes and finite outputs; decode agrees with the full
forward pass (prefill + one decode step == forward at that position)."""
import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.data.tokens import make_batch
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, train_step

B, S = 2, 32


def _batch(cfg, with_labels=True):
    return make_batch(cfg, seed=3, step=0, batch=B, seq=S, with_labels=with_labels)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    params = M.init(jax.random.PRNGKey(0), cfg)
    logits, aux = M.forward(params, cfg, _batch(cfg, with_labels=False))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch).smoke()
    opt_cfg = AdamWConfig(lr=5e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg),
                   donate_argnums=(0,))
    losses = []
    for i in range(8):
        state, m = step(state, _batch(cfg))
        losses.append(float(m["loss"]))
        assert jnp.isfinite(m["loss"]), (arch, i)
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistent_with_forward(arch):
    """Greedy next-token from (prefill + decode) == from full forward."""
    cfg = get_config(arch).smoke()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, with_labels=False)

    logits_full, _ = M.forward(params, cfg, batch)

    # prefill the first S tokens, then compare last-position logits
    cache_len = S + 8
    logits_pre, cache = M.prefill(params, cfg, batch, cache_len)
    lf = logits_full[:, -1].astype(jnp.float32)
    lp = logits_pre[:, -1].astype(jnp.float32)
    assert float(jnp.max(jnp.abs(lf - lp))) < 1e-2, arch

    # one decode step keeps shapes/finiteness
    tok = jnp.argmax(logits_pre[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.full((B, 1), S, jnp.int32)
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
    logits_dec, cache = M.decode_step(params, cfg, tok, pos, cache)
    assert logits_dec.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_dec)))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-9b", "xlstm-125m"])
def test_multi_step_decode_matches_forward(arch):
    """Teacher-forced decode over several steps reproduces forward logits."""
    cfg = get_config(arch).smoke()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, with_labels=False)
    toks = batch["tokens"]
    logits_full, _ = M.forward(params, cfg, batch)

    n_pre = S - 4
    pre_batch = dict(batch, tokens=toks[:, :n_pre])
    _, cache = M.prefill(params, cfg, pre_batch, cache_len=S)
    for t in range(n_pre, S):
        tok = toks[:, t - 1 + 1 : t + 1] if False else toks[:, t : t + 1]
        pos = jnp.full((B, 1), t, jnp.int32)
        # feed ground-truth token at position t-? — teacher forcing uses the
        # true token stream: logits at step t must match forward position t
        logits_dec, cache = M.decode_step(params, cfg, toks[:, t : t + 1], pos, cache)
        err = float(jnp.max(jnp.abs(
            logits_dec[:, 0].astype(jnp.float32) -
            logits_full[:, t].astype(jnp.float32))))
        assert err < 2e-2, (arch, t, err)


def test_param_counts_match_published():
    expect = {
        "qwen2-7b": 7.6e9, "mistral-large-123b": 123e9, "llama3.2-1b": 1.24e9,
        "llama3.2-3b": 3.2e9, "arctic-480b": 477e9, "qwen2-moe-a2.7b": 14.3e9,
        "qwen2-vl-72b": 72.7e9, "recurrentgemma-9b": 9.4e9,
    }
    import math
    from repro.configs import param_specs_struct

    for arch, want in expect.items():
        tree = param_specs_struct(get_config(arch))
        n = sum(math.prod(leaf.shape) for leaf in jax.tree_util.tree_leaves(tree))
        assert abs(n - want) / want < 0.06, (arch, n, want)
