"""Unit-identity tests: the named conversion constants are bit-identical to
the literals they replaced, and the thin-coverage dcsim modules obey their
dimensional contracts at runtime — COP/PUE dimensionless ratios, renewable
W displacing grid W one-for-one, the llm path's W ≡ tok/s × J/tok identity,
and payload GB rebuilt from token counts through the declared constants.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import units as U
from repro.dcsim import capability, colocation, power, renewables, topology
from repro.dcsim import env as E
from repro.lint import validate_bounds

ENV = E.build_env(4, seed=0)


# ---------------------------------------------------------------------------
# the constants are pure renames: exact values pinned
# ---------------------------------------------------------------------------

def test_conversion_constants_are_bit_identical_to_the_old_literals():
    assert U.W_PER_KW == 1000.0
    assert U.MS_PER_H == 3.6e6
    assert U.S_PER_H == 3600.0
    assert U.BYTES_PER_GB == 1e9
    assert U.BYTES_PER_GIB == 2.0 ** 30 == 1073741824.0
    assert U.BYTES_PER_FP32_TOKEN == 4.0


def test_er_table_matches_the_pre_rename_literal_expression():
    nn = topology.node_mix(0, 4)
    er = colocation.er_table(nn)
    coer = colocation.coer_core(nn.shape[1])
    cores = np.array([topology.NODE_TYPES[j].cores
                      for j in range(nn.shape[1])], float)
    expected = (coer * cores[None, :]) @ nn.T.astype(float) * 3600.0
    np.testing.assert_array_equal(er, expected)


def test_cet_est_matches_the_pre_rename_literal_expression():
    ar = E.project_feasible(ENV, jnp.full((ENV.er.shape[0], 4), 0.25), 6)
    got = E.cet_est(ENV, ar, 6)
    expected = jnp.sum(
        ENV.carbon[:, 6][None, :] * E.dp_est(ENV, ar, 6) / 1000.0, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expected))


# ---------------------------------------------------------------------------
# power: COP and PUE are dimensionless ratios
# ---------------------------------------------------------------------------

def test_cop_positive_and_env_power_cop_agrees():
    t = np.asarray(ENV.tsupply)
    c_host = power.cop(t)
    c_env = np.asarray(E.power_cop(ENV))
    np.testing.assert_allclose(c_host, c_env, rtol=1e-6)
    assert (c_host > 0).all()


def test_pue_is_a_dimensionless_ratio_at_least_one():
    # PUE = (IT + CRAC)/IT = 1 + 1/COP: a pure ratio, invariant under any
    # common rescaling of the power unit
    it = np.asarray(ENV.it_idle + ENV.it_dyn)
    crac = power.crac_power(it, np.asarray(ENV.tsupply))
    pue = (it + np.minimum(crac, topology.CRAC_PER_DC * topology.CRAC_MAX_W)) / it
    assert (pue >= 1.0).all()
    it_kw = it / U.W_PER_KW
    crac_kw = crac / U.W_PER_KW
    np.testing.assert_allclose((it_kw + crac_kw) / it_kw, (it + crac) / it,
                               rtol=1e-6)   # float32 leaves


# ---------------------------------------------------------------------------
# renewables: profile W displaces grid W one-for-one
# ---------------------------------------------------------------------------

def test_renewable_profile_units_match_grid_power_displacement():
    tau = 12
    dp = E.grid_power(ENV, jnp.zeros_like(ENV.er), tau)
    dp0 = E.grid_power(ENV._replace(rp=jnp.zeros_like(ENV.rp)),
                       jnp.zeros_like(ENV.er), tau)
    # same unit (W) on both sides: removing the profile raises net draw by
    # exactly rp[:, tau] (up to float32 rounding of the subtraction)
    np.testing.assert_allclose(np.asarray(dp0 - dp),
                               np.asarray(ENV.rp[:, tau]), rtol=1e-6)


def test_renewable_profile_is_nonnegative_w():
    rp = renewables.renewable_profile(
        np.zeros(4), np.full(4, 0.5), np.full(4, 0.5),
        installed_w=1e6, month=6, seed=0)
    assert rp.shape == (4, 24)
    assert (np.asarray(rp) >= 0).all()


# ---------------------------------------------------------------------------
# llm capability: W ≡ tok/s × J/tok, GB ≡ tokens × B/token
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def llm_bundle():
    wl = capability.get_workload("llm")
    return wl, wl.capabilities(4, seed=0)


def test_llm_w_equals_tokens_per_s_times_j_per_token(llm_bundle):
    wl, bundle = llm_bundle
    tok_s = bundle.meta["tokens_per_s_chip"]       # (I, A) token/s/chip
    j_tok = bundle.meta["j_per_token"]             # (I, A) J/token
    chips = np.array([a.chips for a in wl.accel_types], float)  # chip/node
    # token/s/chip × J/token × chip/node == dynamic W/node, by construction
    dyn_w = np.array([a.dyn_w for a in wl.accel_types])
    np.testing.assert_allclose(tok_s * j_tok * chips[None, :],
                               np.broadcast_to(dyn_w, tok_s.shape),
                               rtol=1e-9)


def test_llm_sizes_rebuild_from_token_counts(llm_bundle):
    wl, bundle = llm_bundle
    expected = np.array([
        (p.prompt_mean + p.output_mean) * U.BYTES_PER_FP32_TOKEN
        / U.BYTES_PER_GB + p.extra_payload_gb
        for _, p in wl.families])
    np.testing.assert_array_equal(bundle.sizes, expected)


# ---------------------------------------------------------------------------
# runtime bounds validation
# ---------------------------------------------------------------------------

def test_validate_bounds_green_on_default_env():
    validate_bounds(ENV)


def test_validate_bounds_flags_negative_price():
    bad = ENV._replace(eprice=ENV.eprice - 100.0)
    with pytest.raises(ValueError, match="eprice"):
        validate_bounds(bad)


def test_validate_bounds_flags_broken_origin_simplex():
    bad = ENV._replace(origin=ENV.origin * 2.0)
    with pytest.raises(ValueError, match="origin"):
        validate_bounds(bad)
