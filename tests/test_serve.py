"""Serving layer: ModelServer generation, Fleet routing, EP MoE parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Fleet, ModelServer, Request
from repro.models import moe as moe_mod
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


def test_model_server_generates():
    cfg = get_config("llama3.2-1b").smoke()
    srv = ModelServer(cfg, batch_size=3, cache_len=48)
    reqs = [Request(i, jnp.arange(1, 9, dtype=jnp.int32), max_new=5) for i in range(3)]
    outs = srv.generate(reqs)
    assert set(outs) == {0, 1, 2}
    for toks in outs.values():
        assert len(toks) == 5
        assert all(0 <= t < cfg.vocab_size for t in toks)
    assert srv.tokens_per_second() > 0


def test_model_server_greedy_matches_forward():
    """Server generation (prefill+decode) == argmax of teacher-forced forward."""
    from repro.models import model as M

    cfg = get_config("llama3.2-1b").smoke()
    srv = ModelServer(cfg, batch_size=1, cache_len=64, seed=5)
    prompt = jnp.arange(3, 19, dtype=jnp.int32)  # 16 tokens
    outs = srv.generate([Request(0, prompt, max_new=3)])
    # replicate greedily by running forward with the grown sequence
    toks = list(np.asarray(prompt))
    for _ in range(3):
        batch = {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        logits, _ = M.forward(srv.params, cfg, batch)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert outs[0] == toks[16:], (outs[0], toks[16:])


def test_fleet_routes_by_assignment():
    fleet = Fleet(["llama3.2-1b", "xlstm-125m"], 2, smoke=True,
                  batch_size=2, cache_len=32)
    ar = jnp.array([[10.0, 0.0], [0.0, 10.0]])
    report = fleet.route(ar, requests_per_unit=1, prompt_len=8, max_new=2)
    assert report["total"] > 0
    # traffic lands where the assignment put it
    assert (0, 0) in report["dispatched"] or (1, 1) in report["dispatched"]
    assert (0, 1) not in report["dispatched"]
    assert (1, 0) not in report["dispatched"]


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "arctic-480b"])
def test_ep_moe_matches_global_impl(arch):
    """shard_map EP MoE == the global gather formulation, fwd and grad."""
    cfg = get_config(arch).smoke()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, a1 = moe_mod.moe_apply(p, cfg, x)
    with shd.use_mesh(make_host_mesh()):
        y2, a2 = jax.jit(lambda p_, x_: moe_mod.moe_apply_ep(p_, cfg, x_))(p, x)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert abs(float(a1) - float(a2)) < 1e-3
