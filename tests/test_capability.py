"""Capability layer (``dcsim.capability``): the aibench default is pinned
bit-for-bit to the pre-layer constants, the llm model's derived numbers are
unit-consistent with the roofline (tokens/s/chip × J/token == dynamic W/chip;
er monotone in node counts), the task-type axis ``I`` is fully data-driven
(an I=6 llm env runs all six solvers on scan/batched/month), and per-point
stacked FaultTraces reproduce their per-row single runs."""

import numpy as np
import pytest

from repro import faults as FL
from repro import scenarios as S
from repro.core import ExperimentSpec, run, sweep
from repro.core import gt_drl
from repro.core import schedulers as SCH
from repro.core.ddpg import DDPGConfig
from repro.core.force_directed import FDConfig
from repro.core.genetic import GAConfig
from repro.core.nash import NashConfig
from repro.core.ppo import PPOConfig
from repro.core.ppo_joint import JointPPOConfig
from repro.dcsim import capability as C
from repro.dcsim import colocation, env as E, latency, power, topology

ENV = E.build_env(4, seed=0)
LLM_ENV = E.build_env(4, seed=0, workload="llm")


# ---------------------------------------------------------------------------
# aibench: the extracted implementation IS the old constants, bit for bit
# ---------------------------------------------------------------------------

def test_aibench_pin_bit_for_bit():
    """build_env(workload="aibench") == the default == the pre-layer env."""
    explicit = E.build_env(4, seed=0, workload="aibench")
    instance = E.build_env(4, seed=0, workload=C.AIBenchWorkload())
    for f, a, b, c in zip(ENV._fields, ENV, explicit, instance):
        assert a.dtype == b.dtype == c.dtype, f
        assert bool((a == b).all()) and bool((a == c).all()), f


def test_aibench_bundle_matches_direct_construction():
    """The bundle's fields are exactly the pre-layer build_env ops."""
    cap = C.AIBenchWorkload().capabilities(4, seed=0)
    nn = topology.node_mix(0, 4)
    er = colocation.er_table(nn)
    idle, dyn = power.node_power_arrays(nn.shape[1])
    np.testing.assert_array_equal(cap.er, er)
    np.testing.assert_array_equal(cap.it_idle, nn @ idle)
    np.testing.assert_array_equal(cap.it_dyn, nn @ dyn)
    np.testing.assert_array_equal(cap.nn_total, nn.sum(axis=1).astype(float))
    np.testing.assert_array_equal(
        cap.sizes, [t[2] for t in topology.TASK_TYPES])
    np.testing.assert_array_equal(
        cap.sla_ms, latency.default_sla_ms(er, nn.sum(axis=1)))
    assert cap.task_names == tuple(t[0] for t in topology.TASK_TYPES)


def test_include_tpu_is_aibench_only():
    with pytest.raises(ValueError):
        E.build_env(4, seed=0, workload="llm", include_tpu=True)
    with pytest.raises(ValueError):
        C.resolve(C.LLMWorkload(), include_tpu=True)


# ---------------------------------------------------------------------------
# llm: unit consistency of the derived numbers
# ---------------------------------------------------------------------------

def test_llm_tokens_joules_watts_identity():
    """tokens/s/chip × J/token == dynamic W per chip, per (family, accel)."""
    cap = C.LLMWorkload().capabilities(4, seed=0)
    tok = cap.meta["tokens_per_s_chip"]
    jt = cap.meta["j_per_token"]
    dyn_per_chip = np.array([a.dyn_w / a.chips for a in topology.ACCEL_TYPES])
    np.testing.assert_allclose(tok * jt,
                               np.broadcast_to(dyn_per_chip, tok.shape),
                               rtol=1e-6)


def test_llm_er_monotone_in_node_counts():
    """Adding accelerator nodes to a DC never lowers any family's er."""
    wl = C.LLMWorkload()
    cap = wl.capabilities(4, seed=0)
    nn = cap.meta["nn"]
    rates = cap.meta["tasks_per_h_node"]        # (I, A), all positive
    assert (rates > 0).all()
    bigger = nn.copy()
    bigger[2] += 7                               # more nodes of every type
    er_big = rates @ bigger.T.astype(float)
    assert (er_big[:, 2] > cap.er[:, 2]).all()
    np.testing.assert_array_equal(er_big[:, [0, 1, 3]], cap.er[:, [0, 1, 3]])


def test_aibench_er_monotone_in_node_counts():
    """Same monotonicity through the AIBench colocation table."""
    nn = topology.node_mix(0, 4)
    er = colocation.er_table(nn)
    bigger = nn.copy()
    bigger[1] += 11
    er_big = colocation.er_table(bigger)
    assert (np.asarray(er_big)[:, 1] > np.asarray(er)[:, 1]).all()


def test_llm_derivation_shape_and_physics():
    """Structural sanity of the roofline derivation: shapes line up with the
    family count, bigger models are strictly slower per chip, and the
    service-time the M/M/c model sees is finite and positive."""
    wl = C.LLMWorkload()
    cap = wl.capabilities(4, seed=0)
    i, d = cap.er.shape
    assert i == len(C.LLM_FAMILIES) and d == 4
    assert len(cap.task_names) == i == len(cap.sizes) == len(cap.sla_ms)
    fams = dict(C.LLM_FAMILIES)
    tok = dict(zip(cap.task_names, cap.meta["tokens_per_s_chip"]))
    # 1B chat decodes faster than 7B, which beats the 123B dense model
    assert (tok["chat-1b"] > tok["chat-7b"]).all()
    assert (tok["chat-7b"] > tok["dense-large"]).all()
    # a 480B model cannot fit one chip anywhere
    n_chips = dict(zip(cap.task_names, cap.meta["n_chips"]))
    assert (n_chips["moe-480b"] > 1).all()
    assert np.isfinite(latency.service_ms(cap.er, cap.nn_total)).all()
    assert (cap.er > 0).all() and (cap.sla_ms > 0).all()


def test_llm_no_per_task_time_constants():
    """The llm path never touches the AIBench execution-time tables."""
    import inspect

    src = inspect.getsource(C)
    assert "TASK_TYPES" not in src.split("class LLMWorkload")[1].split(
        "class ")[0]
    assert "base_time_table" not in src


# ---------------------------------------------------------------------------
# the task-type axis is data-driven: I = 6 through every engine + solver
# ---------------------------------------------------------------------------

_TINY_PPO = PPOConfig(horizon=2, episodes=4, iters=1, update_epochs=1)
TINY = {
    "fd": FDConfig(iters=5),
    "ga": GAConfig(population=6, generations=4),
    "nash": NashConfig(sweeps=1, inner_steps=5),
    "ddpg": DDPGConfig(steps=8, batch=4, buffer=16, warmup=4),
    "ppo": JointPPOConfig(ppo=_TINY_PPO),
    "gt-drl": gt_drl.GTDRLConfig(ppo=_TINY_PPO, rounds=1, polish_steps=2,
                                 pretrain_iters=2, pretrain_batch=1),
}


@pytest.mark.parametrize("technique", SCH.TECHNIQUES)
def test_llm_env_runs_every_solver_on_scan(technique):
    spec = ExperimentSpec(technique=technique, hours=2, workload="llm",
                          cfg=TINY[technique])
    res = run(spec, LLM_ENV)
    assert np.isfinite(res["totals"]["carbon_kg"])
    assert len(res["per_epoch"]) == 2


@pytest.mark.parametrize("technique", SCH.TECHNIQUES)
def test_llm_env_runs_batched_and_month(technique):
    spec = ExperimentSpec(technique=technique, hours=2, workload="llm",
                          cfg=TINY[technique])
    rb = run(spec.replace(engine="batched"), [LLM_ENV, LLM_ENV])
    assert rb["totals"]["carbon_kg"].shape == (2,)
    assert np.all(np.isfinite(rb["totals"]["carbon_kg"]))
    rm = run(spec.replace(engine="month", days=2), LLM_ENV)
    assert rm["days"] == 2 and np.isfinite(rm["totals"]["carbon_kg"])


def test_workload_field_forks_the_compile_key():
    from repro.core import experiment as X

    spec = ExperimentSpec(technique="fd", hours=2, cfg=TINY["fd"])
    assert X._engine_key(spec) != X._engine_key(spec.replace(workload="llm"))
    with pytest.raises(ValueError):
        ExperimentSpec(workload=C.LLMWorkload())  # names only, not instances


def test_custom_workload_registration():
    class Tiny(C.WorkloadModel):
        name = "tiny-test"

        def capabilities(self, num_dcs, seed):
            i = 3
            er = np.full((i, num_dcs), 1e6)
            nn_total = np.full(num_dcs, 100.0)
            return C.CapabilityBundle(
                task_names=("a", "b", "c"), er=er,
                it_idle=np.full(num_dcs, 1e4), it_dyn=np.full(num_dcs, 1e5),
                nn_total=nn_total, sizes=np.full(i, 0.1),
                sla_ms=latency.default_sla_ms(er, nn_total), meta={})

    C.register_workload("tiny-test", Tiny)
    try:
        assert "tiny-test" in C.workload_names()
        env = E.build_env(4, seed=0, workload="tiny-test")
        assert env.er.shape == (3, 4)
        res = run(ExperimentSpec(technique="fd", hours=2, cfg=TINY["fd"],
                                 workload="tiny-test"), env)
        assert np.isfinite(res["totals"]["cost_usd"])
    finally:
        C._REGISTRY.pop("tiny-test", None)


# ---------------------------------------------------------------------------
# workload-axis scenario transforms
# ---------------------------------------------------------------------------

def test_workload_mix_shift_preserves_hourly_totals():
    shifted = S.make("workload_mix_shift", toward=(4,), weight=0.6)(LLM_ENV)
    np.testing.assert_allclose(np.asarray(shifted.car).sum(axis=0),
                               np.asarray(LLM_ENV.car).sum(axis=0), rtol=1e-5)
    # mass moved toward the target family
    assert (np.asarray(shifted.car)[4] >= np.asarray(LLM_ENV.car)[4]).all()


def test_context_length_surge_stretches_service_time():
    surged = S.make("context_length_surge", factor=2.0, tasks=(1,))(LLM_ENV)
    np.testing.assert_allclose(np.asarray(surged.er)[1],
                               np.asarray(LLM_ENV.er)[1] / 2.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(surged.sizes)[1],
                               np.asarray(LLM_ENV.sizes)[1] * 2.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(surged.er)[0],
                                  np.asarray(LLM_ENV.er)[0])
    # service time in the M/M/c model stretches by exactly the factor
    np.testing.assert_allclose(
        np.asarray(latency.service_ms(surged.er, surged.nn_total))[1],
        2.0 * np.asarray(latency.service_ms(LLM_ENV.er, LLM_ENV.nn_total))[1],
        rtol=1e-5)


# ---------------------------------------------------------------------------
# per-point fault traces (satellite): stacked == per-row singles
# ---------------------------------------------------------------------------

def test_stack_traces_shape_and_validation():
    traces = [FL.random_trace(ENV, seed=s) for s in range(3)]
    st = FL.stack_traces(traces)
    assert st.avail_mult.shape == (3,) + traces[0].avail_mult.shape
    assert st.rtt_extra_ms.shape == (3,) + traces[0].rtt_extra_ms.shape
    with pytest.raises(ValueError):
        FL.stack_traces([])
    with pytest.raises(ValueError):
        FL.stack_traces([traces[0], FL.no_faults(8)])


def test_per_point_traces_match_single_runs():
    cfg = FDConfig(iters=5)
    envs = [S.make("arrival_resample", std=0.1)(ENV), ENV]
    traces = [FL.random_trace(ENV, seed=s) for s in (3, 4)]
    spec = ExperimentSpec(technique="fd", engine="batched", hours=3, cfg=cfg)
    res = run(spec, envs, faults=FL.stack_traces(traces))
    for i, (e, t) in enumerate(zip(envs, traces)):
        single = run(spec.replace(seeds=(i,)), [e], faults=t)
        for k in res["totals"]:
            np.testing.assert_allclose(res["totals"][k][i],
                                       single["totals"][k][0],
                                       rtol=1e-5, atol=1e-5, err_msg=k)


def test_per_point_traces_reject_mismatch_and_scan():
    cfg = FDConfig(iters=5)
    stacked = FL.stack_traces([FL.random_trace(ENV, seed=0)] * 3)
    spec = ExperimentSpec(technique="fd", engine="batched", hours=3, cfg=cfg)
    with pytest.raises(ValueError):
        run(spec, [ENV, ENV], faults=stacked)     # 3 traces, 2 envs
    with pytest.raises(ValueError):
        run(spec.replace(engine="scan"), ENV, faults=stacked)


def test_sweep_accepts_per_point_trace_sequence():
    cfg = FDConfig(iters=5)
    spec = ExperimentSpec(technique="fd", hours=3, cfg=cfg)
    grid = {"origin_shift": (0.0, 0.8)}
    traces = [FL.dc_crash(ENV, dc=0, start=0, duration=3),
              FL.no_faults(ENV)]
    res = sweep(spec, grid, base_env=ENV, faults=traces)
    unserved = res["results"]["fd"]["totals"]["unserved_demand"]
    assert unserved.shape == (2,)
    # the crash-trace point sheds load; the no-fault point cannot
    assert unserved[1] == 0.0
