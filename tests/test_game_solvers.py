"""Game formulation + all six solvers: constraints, equilibrium, ordering."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg, force_directed, genetic, gt_drl, nash, ppo_joint
from repro.core.game import (GameContext, cloud_objective, nash_residual,
                             fractions_to_ar, uniform_fractions)
from repro.core.ppo import PPOConfig
from repro.dcsim import env as E

ENV = E.build_env(4, seed=0)
PEAK = jnp.zeros((4,))
CTX = GameContext(env=ENV, tau=jnp.int32(18), objective="carbon")
KEY = jax.random.PRNGKey(0)

FAST_GTDRL = gt_drl.GTDRLConfig(
    ppo=PPOConfig(horizon=4, episodes=16, iters=2, update_epochs=2),
    rounds=2, polish_steps=15, pretrain_iters=4)


def _check_result(res):
    f = res.fractions
    assert f.shape == (10, 4)
    np.testing.assert_allclose(np.asarray(jnp.sum(f, axis=1)), 1.0, rtol=1e-4)
    assert bool(jnp.all(f >= -1e-6))
    ar = fractions_to_ar(CTX, f)
    assert bool(jnp.all(ar <= ENV.er * (1 + 1e-5)))
    v = float(cloud_objective(CTX, f, PEAK))
    assert np.isfinite(v)
    return v


def test_nash_solver_improves_and_near_equilibrium():
    res = nash.solve_epoch(None, CTX, PEAK)
    v = _check_result(res)
    v0 = float(cloud_objective(CTX, uniform_fractions(CTX), PEAK))
    assert v < v0
    assert float(nash_residual(CTX, res.fractions, PEAK)) < 0.05


def test_fd_solver():
    res = force_directed.solve_epoch(None, CTX, PEAK)
    v = _check_result(res)
    assert v <= float(cloud_objective(CTX, uniform_fractions(CTX), PEAK)) + 1e-6


def test_ga_solver():
    res = genetic.solve_epoch(KEY, CTX, PEAK, genetic.GAConfig(generations=40))
    v = _check_result(res)
    assert v <= float(cloud_objective(CTX, uniform_fractions(CTX), PEAK)) + 1e-6


def test_ddpg_solver():
    res = ddpg.solve_epoch(KEY, CTX, PEAK, ddpg.DDPGConfig(steps=60))
    _check_result(res)


def test_joint_ppo_solver():
    cfg = ppo_joint.JointPPOConfig(ppo=PPOConfig(horizon=4, episodes=16, iters=4))
    res = ppo_joint.solve_epoch(KEY, CTX, PEAK, cfg)
    _check_result(res)


def test_gt_drl_solver_beats_uniform():
    agents = gt_drl.init_agents(KEY, ENV, FAST_GTDRL)
    agents, res = gt_drl.solve_epoch(KEY, agents, CTX, PEAK, FAST_GTDRL)
    v = _check_result(res)
    v0 = float(cloud_objective(CTX, uniform_fractions(CTX), PEAK))
    assert v < v0


def test_gt_drl_state_action_space_is_per_player():
    """The paper's central claim: GT-DRL agents see |D| dims, not |I|x|D|."""
    d = E.num_dcs(ENV)
    agents = gt_drl.init_agents(KEY, ENV, FAST_GTDRL)
    # stacked leading axis = players; final actor layer outputs |D| logits
    n_layers = len(agents.actor["mlp"]) // 2
    w_last = agents.actor["mlp"][f"w{n_layers-1}"]
    assert w_last.shape[0] == E.num_players(ENV)  # stacked players
    assert w_last.shape[-1] == d                  # |D|-dim action space


def test_gt_drl_cost_objective():
    ctx = GameContext(env=ENV, tau=jnp.int32(9), objective="cost")
    agents = gt_drl.init_agents(KEY, ENV, FAST_GTDRL)
    agents, res = gt_drl.solve_epoch(KEY, agents, ctx, PEAK, FAST_GTDRL)
    v = float(cloud_objective(ctx, res.fractions, PEAK))
    assert np.isfinite(v)
    assert v <= float(cloud_objective(ctx, uniform_fractions(ctx), PEAK)) + 1e-6


def test_nash_residual_zero_only_at_equilibrium():
    f0 = uniform_fractions(CTX)
    r_uniform = float(nash_residual(CTX, f0, PEAK))
    res = nash.solve_epoch(None, CTX, PEAK)
    r_eq = float(nash_residual(CTX, res.fractions, PEAK))
    assert r_eq < r_uniform
