"""Realized-fault execution layer: plan/execute split, failover policies,
graceful degradation, resumable sweeps (repro.faults)."""
import json
import os
import time

import numpy as np
import jax.numpy as jnp
import pytest

from _hyp_compat import given, st

from repro import faults, obs
from repro.core import (ExperimentSpec, register_technique, run, sweep,
                        unregister_technique)
from repro.core.game import SolveResult
from repro.dcsim import env as E
import repro.core.experiment as X

HOURS = 6


@pytest.fixture(scope="module")
def env():
    return E.build_env(4, seed=0)


@pytest.fixture(scope="module")
def crash_trace(env):
    # DC 1 dark for half the short day, plus a WAN partition
    return faults.compose(
        faults.dc_crash(env, dc=1, start=2, duration=3),
        faults.wan_partition(env, a=0, b=2, extra_ms=300.0))


def _totals(res):
    return res["totals"]


# ---------------------------------------------------------------------------
# the contract: faults=None and the identity trace reproduce the plan
# ---------------------------------------------------------------------------

def test_identity_trace_matches_unfaulted_exactly(env):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    base = _totals(run(spec, env))
    ident = _totals(run(spec, env, faults=faults.no_faults(env)))
    for k, v in base.items():
        assert ident[k] == v, k  # bit-for-bit on the unrouted path
    for k in X._FAULT_KEYS:
        assert k not in base       # unfaulted results carry no fault keys
        assert ident[k] == 0.0     # nothing happened

def test_identity_trace_matches_unfaulted_routed(env):
    spec = ExperimentSpec(technique="fd", hours=HOURS, routed=True)
    base = _totals(run(spec, env))
    ident = _totals(run(spec, env, faults=faults.no_faults(env)))
    for k, v in base.items():
        # the routed failover re-split is a ratio round-trip: allclose
        np.testing.assert_allclose(ident[k], v, rtol=1e-5, atol=1e-4)


def test_faulted_engine_is_separate_compile_entry(env):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    k0 = X._engine_key(spec)
    k1 = X._engine_key(spec, faulted=True)
    assert k0 != k1
    # unfaulted lookups normalize the failover policy out of the key
    assert X._engine_key(spec.replace(failover="drop")) == k0
    assert X._engine_key(spec.replace(failover="drop"), faulted=True) != k1


# ---------------------------------------------------------------------------
# hard mid-day crash: finite totals, degradation metrics across techniques
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("technique,kw", [
    ("fd", {}), ("nash", {}), ("gt-drl", {"pretrain": False}),
])
def test_crash_day_finite_with_failover(env, crash_trace, technique, kw):
    spec = ExperimentSpec(technique=technique, hours=HOURS, **kw)
    t = _totals(run(spec, env, faults=crash_trace))
    assert all(np.isfinite(v) for v in t.values()), t
    assert t["failover_moved"] > 0.0   # the planner kept using DC 1
    assert t["unserved_demand"] >= 0.0


def test_total_blackout_prices_unserved(env):
    # every DC dark all day: nowhere to fail over to, everything unserved
    tr = faults.compose(*[faults.dc_crash(env, dc=d, start=0, duration=24)
                          for d in range(E.num_dcs(env))])
    t = _totals(run(ExperimentSpec(technique="fd", hours=HOURS), env,
                    faults=tr))
    assert all(np.isfinite(v) for v in t.values()), t
    assert t["unserved_demand"] > 0.0
    assert t["failover_moved"] == 0.0


def test_drop_policy_shed_vs_renormalize(env, crash_trace):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    ren = _totals(run(spec, env, faults=crash_trace))
    drop = _totals(run(spec.replace(failover="drop"), env,
                       faults=crash_trace))
    assert drop["failover_moved"] == 0.0       # drop never moves mass
    assert drop["unserved_demand"] > 0.0       # ... it sheds it
    assert drop["unserved_demand"] > ren["unserved_demand"]


# ---------------------------------------------------------------------------
# engine parity under faults (scan is the reference)
# ---------------------------------------------------------------------------

def test_faulted_scan_loop_batched_parity(env, crash_trace):
    spec = ExperimentSpec(technique="fd", hours=HOURS,
                          failover="spill_nearest")
    scan = _totals(run(spec, env, faults=crash_trace))
    loop = _totals(run(spec.replace(engine="loop"), env, faults=crash_trace))
    batched = _totals(run(spec.replace(engine="batched"), [env, env],
                          faults=crash_trace))
    for k, v in scan.items():
        np.testing.assert_allclose(loop[k], v, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(batched[k][0], v, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(batched[k][1], batched[k][0])


def test_month_engine_rejects_faults(env):
    with pytest.raises(ValueError, match="month"):
        run(ExperimentSpec(technique="fd", engine="month", hours=HOURS),
            env, faults=faults.no_faults(env))


# ---------------------------------------------------------------------------
# apply_failover unit behavior
# ---------------------------------------------------------------------------

def test_spill_nearest_prefers_low_rtt(env):
    d = E.num_dcs(env)
    # DC 0 crashed; DC 1 is 5ms away, the rest 500ms, all with headroom
    rtt = np.full((d, d), 500.0, dtype=np.float32)
    np.fill_diagonal(rtt, 0.0)
    rtt[0, 1] = rtt[1, 0] = 5.0
    renv = env._replace(rtt=jnp.asarray(rtt),
                        avail=env.avail.at[0].set(0.0))
    i_n = E.num_players(env)
    ar = np.zeros((i_n, d), dtype=np.float32)
    ar[:, 0] = 1000.0  # everything planned onto the dead DC, well under
    # the healthy DCs' headroom so placement is preference, not necessity
    kept, unserved, moved = faults.apply_failover(renv, jnp.asarray(ar), 0,
                                                  "spill_nearest")
    kept = np.asarray(kept)
    assert float(unserved) < 1e-3
    assert np.allclose(float(moved), i_n * 1000.0, rtol=1e-5)
    assert kept[:, 0].sum() == 0.0                    # nothing on the corpse
    assert kept[:, 1].sum() > kept[:, 2:].sum()       # near beats far


def test_apply_failover_routed_conserves_and_caps(env, crash_trace):
    tau = 3  # inside the crash window
    renv = faults.realized_env(env, crash_trace, tau)
    s_n, i_n, d = E.num_sources(env), E.num_players(env), E.num_dcs(env)
    rng = np.random.default_rng(0)
    fr = rng.dirichlet(np.ones(d), size=(s_n, i_n)).astype(np.float32)
    ar3 = E.project_feasible_routed(env, jnp.asarray(fr), tau)  # planned
    kept3, unserved, moved = faults.apply_failover(renv, ar3, tau,
                                                   "renormalize")
    tot = np.asarray(jnp.sum(kept3, axis=0))
    cap = np.asarray(E.capacity_at(renv, tau))
    assert np.all(tot <= cap + 1e-2)                  # realized-capacity cap
    assert float(unserved) >= -1e-3
    # mass conservation up to the drop: planned == kept + unserved
    np.testing.assert_allclose(float(jnp.sum(ar3)),
                               float(jnp.sum(kept3)) + float(unserved),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# numerical graceful degradation
# ---------------------------------------------------------------------------

def test_guard_falls_back_on_nan_solver(env):
    def nan_solve(key, ctx, peak_state, cfg=None):
        fr = jnp.full((E.num_players(ctx.env), E.num_dcs(ctx.env)), jnp.nan)
        return SolveResult(fr, {})

    register_technique("nan-solver", nan_solve, overwrite=True)
    try:
        spec = ExperimentSpec(technique="nan-solver", hours=HOURS)
        t = _totals(run(spec, env))
        assert not all(np.isfinite(v) for v in t.values())  # poisoned
        t = _totals(run(spec.replace(guard=True), env))
        assert all(np.isfinite(v) for v in t.values()), t
        assert t["fallback_hours"] == HOURS   # every hour fell back
    finally:
        unregister_technique("nan-solver")


def test_guard_is_invisible_on_healthy_solver(env):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    base = _totals(run(spec, env))
    guarded = _totals(run(spec.replace(guard=True), env))
    assert guarded["fallback_hours"] == 0.0
    for k, v in base.items():
        assert guarded[k] == v, k


def test_gt_drl_reports_diverged_rounds(env):
    from repro.core import gt_drl as G
    from repro.core.game import GameContext
    import jax
    cfg = G.GTDRLConfig(rounds=2)
    agents = G.init_agents(jax.random.PRNGKey(0), env, cfg, False)
    ctx = GameContext(env=env, tau=jnp.int32(0), objective="carbon",
                      routed=False)
    _, res = G.solve_epoch(jax.random.PRNGKey(1), agents, ctx,
                           jnp.zeros((E.num_dcs(env),)), cfg)
    assert int(res.info["diverged_rounds"]) == 0   # healthy run never rewinds
    assert np.all(np.isfinite(np.asarray(res.fractions)))


# ---------------------------------------------------------------------------
# planned outage stays finite (dark-DC latency guard)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["scan", "loop", "batched"])
def test_planned_full_day_outage_finite(engine):
    from repro import scenarios as S
    env = S.Scenario("dc_outage", {"dc": 1, "start": 0,
                                   "duration": 24}).apply(E.build_env(4, seed=0))
    spec = ExperimentSpec(technique="fd", hours=HOURS, engine=engine)
    t = _totals(run(spec, [env] if engine == "batched" else env))
    vals = {k: (float(np.asarray(v).sum()) if engine == "batched" else v)
            for k, v in t.items()}
    assert all(np.isfinite(v) for v in vals.values()), vals


def test_dark_dc_latency_is_saturated_not_idle_fast(env):
    dark = env._replace(avail=env.avail.at[1].set(0.0))
    i_n, d = E.num_players(env), E.num_dcs(env)
    ar = jnp.zeros((i_n, d))
    lat = np.asarray(E.latency_ms(dark, ar, 0))
    lat_live = np.asarray(E.latency_ms(env, ar, 0))
    assert np.all(np.isfinite(lat))
    # the dead DC quotes WORSE latency than when alive and idle, not better
    assert np.all(lat[:, 1] > lat_live[:, 1])


# ---------------------------------------------------------------------------
# property: routed projection respects realized capacity, conserves mass
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=0, max_value=23))
def test_project_feasible_routed_capacity_and_mass(seed, tau):
    env = E.build_env(4, seed=0)
    rng = np.random.default_rng(seed)
    # random availability, including fully-dark DCs
    avail = rng.uniform(0.0, 1.0, np.asarray(env.avail).shape)
    avail[rng.integers(avail.shape[0])] = 0.0
    env = env._replace(avail=jnp.asarray(avail.astype(np.float32)))
    s_n, i_n, d = E.num_sources(env), E.num_players(env), E.num_dcs(env)
    fr = rng.dirichlet(np.ones(d), size=(s_n, i_n)).astype(np.float32)
    ar3 = np.asarray(E.project_feasible_routed(env, jnp.asarray(fr), tau))
    assert np.all(np.isfinite(ar3))
    assert np.all(ar3 >= -1e-6)
    tot = ar3.sum(axis=0)
    cap = np.asarray(E.capacity_at(env, tau))
    assert np.all(tot <= cap + 1e-2 + 1e-5 * cap)   # never above capacity
    # conserves demand mass up to drop (water-fill may shed, never create)
    demand = float(np.asarray(env.car)[:, tau].sum())
    assert ar3.sum() <= demand * (1 + 1e-5) + 1e-3


# ---------------------------------------------------------------------------
# resumable sweeps
# ---------------------------------------------------------------------------

GRID = {"wan_degradation": (1.0, 2.0, 4.0)}


def test_sweep_kill_resume_roundtrip(env, tmp_path, monkeypatch):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    ref = sweep(spec, GRID, base_env=env)
    journal = str(tmp_path / "journal")

    with pytest.raises(faults.KilledMidSweep):
        with faults.inject_kill_after(2):
            sweep(spec, GRID, base_env=env, resume_dir=journal)
    assert faults.SweepJournal  # journal dir holds the completed prefix
    assert len(os.listdir(journal)) == 2

    calls = []
    orig = X._run_batched
    monkeypatch.setattr(X, "_run_batched",
                        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    res = sweep(spec, GRID, base_env=env, resume_dir=journal)
    assert len(calls) == 1             # completed chunks are NOT recomputed
    assert res["resume"]["restored"] == 2
    assert res["resume"]["computed"] == 1
    for k, v in ref["results"]["fd"]["totals"].items():
        np.testing.assert_allclose(res["results"]["fd"]["totals"][k], v)
    for k, v in ref["results"]["fd"]["per_epoch"].items():
        np.testing.assert_allclose(res["results"]["fd"]["per_epoch"][k], v)


def test_sweep_journal_rejects_different_sweep(env, tmp_path):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    journal = str(tmp_path / "journal")
    sweep(spec, GRID, base_env=env, resume_dir=journal)
    with pytest.raises(ValueError, match="different sweep"):
        sweep(spec.replace(hours=HOURS - 1), GRID, base_env=env,
              resume_dir=journal)


def test_sweep_retries_with_backoff(env, tmp_path, monkeypatch):
    spec = ExperimentSpec(technique="fd", hours=HOURS)
    orig = X._run_batched
    fails = {"left": 2}

    def flaky(*a, **kw):
        if fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("transient")
        return orig(*a, **kw)

    ref = sweep(spec, GRID, base_env=env)
    monkeypatch.setattr(X, "_run_batched", flaky)
    res = sweep(spec, GRID, base_env=env,
                resume_dir=str(tmp_path / "journal"), max_retries=3,
                backoff_s=0.0)
    assert res["resume"]["retries"] == 2
    for k, v in ref["results"]["fd"]["totals"].items():
        np.testing.assert_allclose(res["results"]["fd"]["totals"][k], v)


def test_sweep_retry_budget_exhausts(env, tmp_path, monkeypatch):
    monkeypatch.setattr(X, "_run_batched",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            RuntimeError("always broken")))
    with pytest.raises(RuntimeError, match="always broken"):
        sweep(ExperimentSpec(technique="fd", hours=HOURS), GRID,
              base_env=env, resume_dir=str(tmp_path / "journal"),
              max_retries=1, backoff_s=0.0)


def test_run_with_retries_backoff_schedule():
    from repro.distributed import fault_tolerance as FT
    sleeps = []
    calls = {"n": 0}

    def step(_):
        calls["n"] += 1
        if calls["n"] <= 3:
            raise FT.SimulatedFailure()

    FT.run_with_retries(step, total_steps=1, save_every=1,
                        save_fn=lambda s: None, restore_fn=lambda: 0,
                        backoff_s=0.1, sleep_fn=sleeps.append)
    assert sleeps == [0.1, 0.2, 0.4]   # exponential


def test_call_with_timeout():
    assert faults.call_with_timeout(lambda: 42, None) == 42
    assert faults.call_with_timeout(lambda: 42, 5.0) == 42
    with pytest.raises(faults.PointTimeout):
        faults.call_with_timeout(lambda: time.sleep(10), 0.2)


# ---------------------------------------------------------------------------
# atomic records
# ---------------------------------------------------------------------------

def test_records_truncated_trailing_line_skipped(tmp_path):
    path = str(tmp_path / "records.jsonl")
    obs.write_record({"kind": "a", "x": 1}, path)
    obs.write_record({"kind": "b", "x": 2}, path)
    with open(path, "a") as f:
        f.write('{"kind": "c", "x"')  # the torn tail of a crashed append
    recs = obs.load_records(path)
    assert [r["kind"] for r in recs] == ["a", "b"]
    # mid-file corruption is NOT silently skipped
    with open(path, "w") as f:
        f.write('{"kind": "a"\n{"kind": "b", "x": 2}\n')
    with pytest.raises(json.JSONDecodeError):
        obs.load_records(path)
