# Tier-1 verify + benchmark entry points (see ROADMAP.md).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check lint bench bench-quick bench-scenarios bench-smoke sweep-smoke \
        obs-smoke faults-smoke llm-smoke scoreboard

# PYTEST_ARGS lets CI add plugins the container image lacks
# (e.g. PYTEST_ARGS="--timeout=300" with pytest-timeout installed)
check: lint
	$(PY) -m pytest -x -q $(PYTEST_ARGS)

# static analysis: the repo-native pass (trace purity, compile-key
# completeness, pytree schemas, tap registry, units of measure, bounds
# invariants — see README "Static analysis") plus ruff when available
# (pinned in requirements-dev.txt; skipped, not failed, where it isn't
# installed). LINT_FORMAT=github makes CI violations annotate PR lines.
LINT_FORMAT ?= text
lint:
	$(PY) -m repro.lint --format=$(LINT_FORMAT)
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
		$(PY) -m ruff check . ; \
	else \
		echo "ruff not installed; skipping (pip install -r requirements-dev.txt)" ; \
	fi

bench:
	$(PY) -m benchmarks.run

bench-quick:
	REPRO_BENCH_QUICK=1 $(PY) -m benchmarks.run

bench-scenarios:
	$(PY) -m benchmarks.run --only scenarios

# perf-trajectory smoke: machine-readable engine timings, committed per perf
# PR (includes engine/day_scan_routed — the (S, I, D) routing-tensor day —
# so the per-source axis' overhead is tracked from PR 4 onward)
bench-smoke:
	REPRO_BENCH_QUICK=1 $(PY) -m benchmarks.run --only scenarios,engine --json BENCH_engine.json

# telemetry smoke: taps-on vs taps-off parity over a 3-hour day for two
# techniques, run records written, scoreboard rendered from them (see
# repro.obs; the full 5-technique artifact is `python examples/run_obs.py`)
obs-smoke:
	$(PY) examples/run_obs.py --quick

# robustness smoke: a tiny FaultTrace day across failover policies plus one
# kill/resume sweep round-trip (see repro.faults; full day via
# `python examples/run_faults.py`)
faults-smoke:
	$(PY) examples/run_faults.py --quick

# workload-capability smoke: all six techniques on the token-grounded llm
# workload (roofline-derived model-family env) across a workload_mix_shift
# day (see dcsim.capability; full day via `python examples/run_llm_mix.py`)
llm-smoke:
	$(PY) examples/run_llm_mix.py --quick

# re-render the committed SCOREBOARD.md from the committed run records
scoreboard:
	$(PY) -m repro.obs runs/records.jsonl -o SCOREBOARD.md

# severity-sweep smoke: the declarative ExperimentSpec sweep API end to end
# (2x2 wan_degradation x origin_shift grid, routed fd vs a source-blind
# technique registered through the public register_technique hook)
sweep-smoke:
	$(PY) examples/run_sweep.py --quick
