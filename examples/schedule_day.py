"""Reproduce the paper's day-scale experiment interactively (Figs. 7/9/11):
all six techniques through 24 hourly epochs; per-epoch carbon and the
monthly-peak cost dynamics printed as a table.

    PYTHONPATH=src python examples/schedule_day.py --objective carbon --dcs 4
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.core.schedulers import TECHNIQUES, run_day
from repro.dcsim import env as E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", choices=E.OBJECTIVES, default="carbon")
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--pattern", choices=("sinusoidal", "flat", "weekday",
                                          "weekend", "bursty"),
                    default="sinusoidal")
    ap.add_argument("--techniques", default=",".join(TECHNIQUES))
    args = ap.parse_args()

    env = E.build_env(args.dcs, pattern=args.pattern, seed=0)
    metric = "carbon_kg" if args.objective == "carbon" else "cost_usd"
    results = {}
    for t in args.techniques.split(","):
        res = run_day(env, t, args.objective, seed=0, hours=24)
        results[t] = res
        print(f"{t:7s} total {metric}: {res['totals'][metric]:12.1f}")

    print("\nper-epoch", metric)
    header = "hour | " + " | ".join(f"{t:>8s}" for t in results)
    print(header)
    for h in range(24):
        row = f"{h:4d} | " + " | ".join(
            f"{results[t]['per_epoch'][h][metric]:8.1f}" for t in results)
        print(row)


if __name__ == "__main__":
    main()
