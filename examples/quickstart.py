"""Quickstart: the paper's GT-DRL scheduler end to end in ~a minute on CPU.

Builds the 4-DC geo-distributed cloud, solves one day of hourly epochs with
GT-DRL and the NASH baseline, and prints the carbon/cost ledger — the
minimal version of the paper's Fig. 7 experiment.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core.game import GameContext, cloud_objective, uniform_fractions
from repro.core.schedulers import run_day
from repro.dcsim import env as E


def main():
    env = E.build_env(num_dcs=4, month=6, pattern="sinusoidal", seed=0)
    print(f"cloud: {E.num_dcs(env)} data centers, {E.num_players(env)} task types")
    ctx = GameContext(env=env, tau=jnp.int32(18), objective="carbon")
    v0 = float(cloud_objective(ctx, uniform_fractions(ctx), jnp.zeros((4,))))
    print(f"uniform split at 6 PM UTC: {v0:.1f} kg CO2/h")

    for technique in ("nash", "gt-drl"):
        res = run_day(env, technique, objective="carbon", seed=0, hours=24)
        t = res["totals"]
        print(f"{technique:7s}: day carbon {t['carbon_kg']:9.1f} kg, "
              f"violations {t['violation']:.2e}")
    print("done — see benchmarks/ for the full paper protocol.")


if __name__ == "__main__":
    main()
