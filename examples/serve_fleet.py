"""Control plane meets data plane: GT-DRL routes real inference traffic.

Stands up a miniature serving fleet (3 architectures × 2 data centers,
reduced configs), lets the paper's GT-DRL scheduler compute the arrival-rate
split for the current hour, and dispatches actual batched prefill+decode
requests according to that split — the full loop the paper's CWM/DWM
architecture describes.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import gt_drl
from repro.core.game import GameContext, fractions_to_ar
from repro.dcsim import env as E
from repro.launch.serve import Fleet


def main():
    archs = ["llama3.2-1b", "qwen2-moe-a2.7b", "recurrentgemma-9b"]
    num_dcs = 2
    print(f"fleet: {archs} x {num_dcs} DCs (reduced configs)")
    fleet = Fleet(archs, num_dcs, smoke=True, batch_size=4, cache_len=64)

    env = E.build_env(4, seed=0)
    ctx = GameContext(env=env, tau=jnp.int32(14), objective="cost")
    cfg = gt_drl.GTDRLConfig(rounds=2, pretrain_iters=0)
    agents = gt_drl.init_agents(jax.random.PRNGKey(0), env, cfg)
    agents, res = gt_drl.solve_epoch(
        jax.random.PRNGKey(1), agents, ctx, jnp.zeros((4,)), cfg)
    ar = fractions_to_ar(ctx, res.fractions)
    print("GT-DRL arrival-rate split (tasks/h), first 3 types x first 2 DCs:")
    print(jnp.round(ar[:3, :2]).astype(int))

    report = fleet.route(ar[: len(archs), :num_dcs], requests_per_unit=2,
                         prompt_len=12, max_new=4)
    print(f"dispatched {report['total']} requests")
    for (i, d), n in sorted(report["dispatched"].items()):
        print(f"  arch={archs[i]:18s} dc={d}: {n} requests")
    for k, tps in report["per_server_tps"].items():
        print(f"  server {k}: {tps:.1f} tok/s decode")


if __name__ == "__main__":
    main()
