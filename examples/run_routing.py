"""Per-source request routing: the (S, I, D) decision surface in action.

Runs the ``routing`` scenario suite (origins shifted east/west, regional
flash crowds, degraded WAN, priced SLAs) with the routed engines — each
technique is ONE compiled ``run_days_batched`` call over the whole suite —
and then demonstrates the headline claim: on a non-uniform ``origin_shift``
day, optimizing *which region's* requests go to which DC measurably cuts
the SLA-miss bill versus the source-blind (I, D) split PR 3 could express,
with both priced by the same routed simulator.

    PYTHONPATH=src python examples/run_routing.py
    PYTHONPATH=src python examples/run_routing.py --techniques fd,nash,gt-drl
    PYTHONPATH=src python examples/run_routing.py --hours 12 --scenario west-evening
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro import scenarios as S
from repro.core import schedulers as SCH
from repro.core.game import GameContext
from repro.dcsim import env as E


def run_source_blind_day(env, technique, objective, *, seed=0, hours=24,
                         cfg=None):
    """PR 3's decision surface priced under the routed simulator.

    Solves the unrouted (I, D) game each hour and broadcasts the split to
    every source region — every region's requests get the same treatment —
    then bills the day with the per-(source, task) SLA pricing. The routed
    engine must beat this to prove the new axis earns its keep.
    """
    solver = SCH.get_scheduler(technique, env, objective,
                               **({"cfg": cfg} if cfg is not None else {}))
    s, d = E.num_sources(env), E.num_dcs(env)
    key = jax.random.PRNGKey(seed)
    _, key = jax.random.split(key)
    peak = jnp.zeros((d,))
    totals = {"cost_usd": 0.0, "sla_miss_cost_usd": 0.0, "carbon_kg": 0.0}
    for tau in range(hours):
        key, ks = jax.random.split(key)
        ctx = GameContext(env=env, tau=jnp.int32(tau), objective=objective)
        res = solver(ks, ctx, peak)
        blind = jnp.broadcast_to(res.fractions, (s,) + res.fractions.shape)
        ar3 = E.project_feasible_routed(env, blind, jnp.int32(tau))
        peak, m = E.step_epoch(env, peak, ar3, jnp.int32(tau))
        for k in totals:
            totals[k] += float(m[k])
    return totals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--hours", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--techniques", default="fd,nash")
    ap.add_argument("--scenario", default="east-business-day",
                    help="suite day for the routed-vs-source-blind duel")
    args = ap.parse_args()

    base = E.build_env(args.dcs, seed=args.seed)
    suite = S.build_suite("routing", base)
    names = [n for n, _ in suite]
    envs = [e for _, e in suite]
    techniques = args.techniques.split(",")
    print(f"suite=routing days={names} objective=cost_sla routed=True\n")

    print(f"{'technique':9s} {'cost_usd':>14s} {'sla_usd':>12s} "
          f"{'carbon_kg':>12s} {'mean_lat_ms':>12s} {'wall_s':>7s}")
    for t in techniques:
        t0 = time.time()
        res = SCH.run_days_batched(envs, t, "cost_sla", hours=args.hours,
                                   seeds=[args.seed] * len(envs), routed=True)
        wall = time.time() - t0
        tot, pe = res["totals"], res["per_epoch"]
        print(f"{t:9s} {tot['cost_usd'].mean():14.1f} "
              f"{tot['sla_miss_cost_usd'].mean():12.1f} "
              f"{tot['carbon_kg'].mean():12.1f} "
              f"{pe['latency_ms'].mean():12.1f} {wall:7.1f}")

    # -- the duel: routed vs source-blind on a shifted-origin day ------------
    duel_env = envs[names.index(args.scenario)]
    t = techniques[0]
    print(f"\nrouting vs source-blind ({t}, scenario={args.scenario}, "
          f"{args.hours}h, same routed simulator):")
    routed = SCH.run_day(duel_env, t, "cost_sla", seed=args.seed,
                         hours=args.hours, routed=True)["totals"]
    blind = run_source_blind_day(duel_env, t, "cost_sla", seed=args.seed,
                                 hours=args.hours)
    for k in ("sla_miss_cost_usd", "cost_usd", "carbon_kg"):
        r, b = routed[k], blind[k]
        cut = 100.0 * (b - r) / max(abs(b), 1e-9)
        print(f"  {k:18s} blind={b:14.1f}  routed={r:14.1f}  ({cut:+5.1f}%)")
    assert routed["sla_miss_cost_usd"] < blind["sla_miss_cost_usd"], (
        "routing toward nearby DCs must cut the SLA-miss bill")
    print("\nrouting toward nearby DCs cut the SLA-miss bill — the RTT "
          "matrix is a real decision surface now.")


if __name__ == "__main__":
    main()
