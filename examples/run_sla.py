"""SLA/latency evaluation: all six techniques on the ``cost_sla`` objective
over the ``latency`` scenario suite — each technique is ONE compiled
``run_days_batched`` call (the paper's protocol plus the beyond-paper
performance term: queueing latency and priced SLA misses).

    PYTHONPATH=src python examples/run_sla.py
    PYTHONPATH=src python examples/run_sla.py --techniques fd,nash --hours 12
    PYTHONPATH=src python examples/run_sla.py --objective cost   # SLA-blind

Prints, per technique, the suite-mean daily cost (which includes the SLA
bill), the SLA-miss bill alone, carbon, and the request-weighted mean
latency — so the carbon/cost-vs-performance trade the paper claims "without
compromising computational performance" is finally measurable.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

from repro import scenarios as S
from repro.core.schedulers import TECHNIQUES, run_days_batched
from repro.dcsim import env as E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", choices=E.OBJECTIVES,
                    default="cost_sla")
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--hours", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--techniques", default=",".join(TECHNIQUES))
    args = ap.parse_args()

    base = E.build_env(args.dcs, seed=args.seed)
    suite = S.build_suite("latency", base)
    names = [n for n, _ in suite]
    envs = [e for _, e in suite]
    print(f"suite=latency days={names} objective={args.objective}\n")

    print(f"{'technique':9s} {'cost_usd':>14s} {'sla_usd':>12s} "
          f"{'carbon_kg':>12s} {'mean_lat_ms':>12s} {'wall_s':>7s}")
    for t in args.techniques.split(","):
        t0 = time.time()
        res = run_days_batched(envs, t, args.objective, hours=args.hours,
                               seeds=[args.seed] * len(envs))
        wall = time.time() - t0
        tot, pe = res["totals"], res["per_epoch"]
        lat = pe["latency_ms"].mean()  # suite × epoch mean of the hourly means
        print(f"{t:9s} {tot['cost_usd'].mean():14.1f} "
              f"{tot['sla_miss_cost_usd'].mean():12.1f} "
              f"{tot['carbon_kg'].mean():12.1f} {lat:12.1f} {wall:7.1f}")

    print("\nper scenario-day SLA bill (last technique):")
    for i, n in enumerate(names):
        print(f"  {n:18s} sla_usd={tot['sla_miss_cost_usd'][i]:12.1f} "
              f"mean_lat_ms={pe['latency_ms'][i].mean():8.1f}")


if __name__ == "__main__":
    main()
