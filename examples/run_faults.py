"""Realized faults end to end: plan/execute split, failover policies,
kill/resume sweeps.

Part 1 runs one day where DC 1 hard-crashes mid-afternoon and the 0↔2 WAN
link degrades — but the planner never hears about it: solvers keep
optimizing the healthy env while ``repro.faults.execute_hour`` re-projects
each hour's allocation against realized capacity. The same trace replays
under each failover policy, so the table shows what the policy choice is
worth: ``renormalize``/``spill_nearest`` serve the displaced load at a
degradation cost, ``drop`` sheds it as unserved demand.

Part 2 journals a severity sweep to disk, kills it mid-grid with the
deterministic ``inject_kill_after`` switch, then re-runs the same call:
the journal restores the completed chunks and only the remainder computes,
and the totals match an unkilled run exactly.

    PYTHONPATH=src python examples/run_faults.py
    PYTHONPATH=src python examples/run_faults.py --quick   # make faults-smoke
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil
import tempfile
import time

import numpy as np

from repro import faults
from repro.core import ExperimentSpec, run, sweep
from repro.dcsim import env as E


def faulted_day(env, hours, technique):
    trace = faults.compose(
        faults.dc_crash(env, dc=1, start=hours // 3, duration=hours // 2),
        faults.wan_partition(env, a=0, b=2, extra_ms=300.0),
    )
    planned = run(ExperimentSpec(technique=technique, hours=hours), env)
    print(f"{'policy':15s} {'carbon_kg':>10s} {'unserved':>12s} "
          f"{'moved':>12s} {'degraded_sla$':>14s}")
    print(f"{'(no faults)':15s} {planned['totals']['carbon_kg']:10.1f} "
          f"{'—':>12s} {'—':>12s} {'—':>14s}")
    results = {}
    for policy in faults.POLICIES:
        res = run(ExperimentSpec(technique=technique, hours=hours,
                                 failover=policy), env, faults=trace)
        t = res["totals"]
        assert all(np.isfinite(v) for v in t.values()), policy
        results[policy] = t
        print(f"{policy:15s} {t['carbon_kg']:10.1f} "
              f"{t['unserved_demand']:12.1f} {t['failover_moved']:12.1f} "
              f"{t['degraded_sla_cost_usd']:14.1f}")
    assert results["drop"]["failover_moved"] == 0.0
    assert results["drop"]["unserved_demand"] > 0.0
    assert results["renormalize"]["failover_moved"] > 0.0
    return results


def kill_resume_sweep(env, hours):
    grid = {"wan_degradation": (1.0, 2.0, 4.0)}
    spec = ExperimentSpec(technique="fd", hours=hours)
    journal = tempfile.mkdtemp(prefix="faults_resume_")
    try:
        reference = sweep(spec, grid, base_env=env)
        try:
            with faults.inject_kill_after(2):
                sweep(spec, grid, base_env=env, resume_dir=journal)
            raise AssertionError("the injected kill did not fire")
        except faults.KilledMidSweep:
            pass
        resumed = sweep(spec, grid, base_env=env, resume_dir=journal)
        meta = resumed["resume"]
        print(f"killed after {meta['restored']} of {meta['chunks']} chunks; "
              f"resume computed the remaining {meta['computed']} "
              f"(retries={meta['retries']})")
        for k, v in reference["results"]["fd"]["totals"].items():
            assert np.allclose(resumed["results"]["fd"]["totals"][k], v), k
        print("resumed totals identical to the unkilled sweep")
    finally:
        shutil.rmtree(journal, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--hours", type=int, default=24)
    ap.add_argument("--technique", default="fd")
    ap.add_argument("--quick", action="store_true",
                    help="6-hour day (the `make faults-smoke` setting)")
    args = ap.parse_args()
    if args.quick:
        args.hours = 6

    env = E.build_env(args.dcs, seed=0)
    t0 = time.time()
    print("— realized faults: DC 1 crash + 0↔2 WAN partition, "
          f"{args.hours}h day, technique={args.technique} —")
    faulted_day(env, args.hours, args.technique)
    print("\n— kill/resume severity sweep —")
    kill_resume_sweep(env, args.hours)
    print(f"\nall good in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
