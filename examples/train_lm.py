"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with checkpointing and automatic resume (deliverable b).

The config is a genuine member of the llama3.2 family (16 layers, width
scaled down to ~100M params) — not the unit-test smoke config. On CPU this
takes a few minutes; interrupt it and re-run to watch the fault-tolerant
resume path restore bitwise-identically.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train_loop


def lm_100m():
    base = get_config("llama3.2-1b")
    return dataclasses.replace(
        base,
        name="llama3.2-100m",
        num_layers=8,
        d_model=640,
        num_heads=10,
        num_kv_heads=2,
        head_dim=64,
        d_ff=1792,
        vocab_size=32768,
        dtype="float32",
        param_dtype="float32",
        remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_100m()
    n = cfg.param_count()
    print(f"training {cfg.name}: ~{n/1e6:.0f}M params, {args.steps} steps")

    import repro.launch.train as T

    # train_loop resolves configs by name; pass ours via a tiny shim
    orig = T.build
    T.build = lambda arch, smoke, lr, quantize_moments: (cfg, orig(arch, True, lr, quantize_moments)[1])
    try:
        res = train_loop(
            arch="llama3.2-1b", smoke=False, steps=args.steps,
            batch=args.batch, seq=args.seq, lr=6e-4, seed=0,
            ckpt_dir=args.ckpt_dir, save_every=100, log_every=20)
    finally:
        T.build = orig
    print(f"loss: {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f} "
          f"over {len(res['losses'])} steps (resumable at {args.ckpt_dir})")


if __name__ == "__main__":
    main()
