"""The token-grounded llm workload through all six techniques.

``build_env(workload="llm")`` replaces the paper's hand-set AIBench task
constants with model families from the ``configs/`` zoo: each DC's tasks/h,
W and ms are *derived* from the roofline constants applied to that DC's
accelerator mix (``dcsim/capability.py`` — tokens/sec/chip from the
compute/memory/collective bottleneck, J/token from node power, KV-cache
occupancy batching). Task classes become model families, so the
``workload_mix_shift`` day evaluated here — traffic tilting from the small
chat models toward the 480B MoE mid-day — is a *workload* severity axis
orthogonal to grid events: total arrivals per hour are unchanged, but the
fleet-wide J/token of the demanded mix moves, and schedulers that chase
carbon/price signals must now also respect wildly different per-family
capability tables.

    PYTHONPATH=src python examples/run_llm_mix.py
    PYTHONPATH=src python examples/run_llm_mix.py --quick   # make llm-smoke
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import numpy as np

from repro import scenarios as S
from repro.core import ExperimentSpec, run
from repro.core import gt_drl
from repro.core.ddpg import DDPGConfig
from repro.core.force_directed import FDConfig
from repro.core.genetic import GAConfig
from repro.core.nash import NashConfig
from repro.core.ppo import PPOConfig
from repro.core.ppo_joint import JointPPOConfig
from repro.core.schedulers import TECHNIQUES
from repro.dcsim import capability as C
from repro.dcsim import env as E

_SMOKE_PPO = PPOConfig(horizon=2, episodes=8, iters=2, update_epochs=1)
SMOKE_CFGS = {
    "fd": FDConfig(iters=20),
    "ga": GAConfig(population=8, generations=10),
    "nash": NashConfig(sweeps=1, inner_steps=10),
    "ddpg": DDPGConfig(steps=16, batch=8, buffer=64, warmup=8),
    "ppo": JointPPOConfig(ppo=_SMOKE_PPO),
    "gt-drl": gt_drl.GTDRLConfig(ppo=_SMOKE_PPO, rounds=2, polish_steps=5,
                                 pretrain_iters=4, pretrain_batch=2),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--hours", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--weight", type=float, default=0.5,
                    help="workload_mix_shift blend toward the 480B MoE")
    ap.add_argument("--quick", action="store_true",
                    help="6 hours, tiny solver budgets (`make llm-smoke`)")
    args = ap.parse_args()
    hours = 6 if args.quick else args.hours

    env = E.build_env(args.dcs, seed=args.seed, workload="llm")
    fams = dict(C.LLM_FAMILIES)
    names = tuple(fams)
    moe = names.index("moe-480b")

    cap = C.LLMWorkload().capabilities(args.dcs, args.seed)
    print(f"llm capability layer: {len(names)} model families x "
          f"{args.dcs} DCs (accelerator mixes from topology.accel_mix)\n")
    print(f"{'family':14s} {'arch':20s} {'tok/s/chip':>11s} {'J/token':>9s} "
          f"{'chips':>6s} {'bound':>10s}")
    for i, n in enumerate(names):
        print(f"{n:14s} {fams[n].arch:20s} "
              f"{cap.meta['tokens_per_s_chip'][i].mean():11.0f} "
              f"{cap.meta['j_per_token'][i].mean():9.3f} "
              f"{cap.meta['n_chips'][i].max():6d} "
              f"{cap.meta['bottleneck'][i, 0]:>10s}")

    # the workload-mix day: traffic tilts toward the 480B MoE mid-day
    day = S.make("workload_mix_shift", toward=(moe,), weight=args.weight,
                 start=8, duration=10)(env)

    print("\nsix techniques on the mix-shift day "
          f"(weight={args.weight} toward moe-480b, hours={hours}):\n")
    print(f"{'technique':10s} {'carbon_kg':>11s} {'cost_usd':>11s} "
          f"{'violation':>10s} {'wall_s':>7s}")
    totals = {}
    for t in TECHNIQUES:
        spec = ExperimentSpec(technique=t, objective="carbon", hours=hours,
                              seed=args.seed, workload="llm",
                              cfg=SMOKE_CFGS[t] if args.quick else None)
        t0 = time.time()
        res = run(spec, day)
        wall = time.time() - t0
        totals[t] = res["totals"]
        print(f"{t:10s} {res['totals']['carbon_kg']:11.1f} "
              f"{res['totals']['cost_usd']:11.1f} "
              f"{res['totals']['violation']:10.3f} {wall:7.1f}")

    for t in TECHNIQUES:
        assert np.isfinite(totals[t]["carbon_kg"]), t
        assert np.isfinite(totals[t]["cost_usd"]), t
    base = run(ExperimentSpec(technique="fd", objective="carbon", hours=hours,
                              seed=args.seed, workload="llm",
                              cfg=SMOKE_CFGS["fd"] if args.quick else None),
               env)
    print(f"\nfd on the unshifted day: {base['totals']['carbon_kg']:.1f} kg "
          "(mix shift moves the demanded J/token, same hourly arrivals); "
          f"all six techniques finite on the derived I={len(names)} env.")


if __name__ == "__main__":
    main()
