"""Evaluate one scheduler across a named scenario suite — the whole suite is
simulated in ONE compiled vmapped call (run_days_batched):

    PYTHONPATH=src python examples/stress_suite.py --suite stress --technique fd
    PYTHONPATH=src python examples/stress_suite.py --suite grid_events --technique nash

Prints a per-scenario carbon / cost / violation table plus the fleet totals.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

from repro import scenarios as S
from repro.core.schedulers import TECHNIQUES, run_days_batched
from repro.dcsim import env as E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=S.suite_names(), default="stress")
    ap.add_argument("--technique", choices=TECHNIQUES, default="fd")
    ap.add_argument("--objective", choices=("carbon", "cost"), default="carbon")
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    base = E.build_env(args.dcs, seed=args.seed)
    suite = S.build_suite(args.suite, base)
    names = [n for n, _ in suite]
    envs = [e for _, e in suite]

    t0 = time.time()
    res = run_days_batched(envs, args.technique, args.objective,
                           seeds=[args.seed] * len(envs))
    dt = time.time() - t0

    print(f"suite={args.suite} technique={args.technique} "
          f"objective={args.objective} days={len(envs)} wall={dt:.1f}s")
    print(f"{'scenario':20s} {'carbon_kg':>12s} {'cost_usd':>12s} {'violation':>10s}")
    for i, name in enumerate(names):
        print(f"{name:20s} {res['totals']['carbon_kg'][i]:12.1f} "
              f"{res['totals']['cost_usd'][i]:12.1f} "
              f"{res['totals']['violation'][i]:10.2f}")
    print(f"{'TOTAL':20s} {res['totals']['carbon_kg'].sum():12.1f} "
          f"{res['totals']['cost_usd'].sum():12.1f} "
          f"{res['totals']['violation'].sum():10.2f}")


if __name__ == "__main__":
    main()
