"""Month-scale episode: one compiled call scans a scheduler across a whole
month of days (weekday/weekend traffic, per-day arrival resamples), threading
the monthly peak-demand state — the peak charge becomes a planning signal:

    PYTHONPATH=src python examples/run_month.py --technique fd --days 30
    PYTHONPATH=src python examples/run_month.py --technique nash --objective cost

Prints per-day carbon / cost / running monthly peak, then the month totals.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

from repro import scenarios as S
from repro.core.schedulers import TECHNIQUES, run_month
from repro.dcsim import env as E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--technique", choices=TECHNIQUES, default="fd")
    ap.add_argument("--objective", choices=E.OBJECTIVES, default="carbon")
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--days", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    base = E.build_env(args.dcs, seed=args.seed)
    month = S.build_month(base, days=args.days, seed=args.seed)
    names = [n for n, _ in month]
    envs = [e for _, e in month]

    t0 = time.time()
    res = run_month(envs, args.technique, args.objective, seed=args.seed)
    dt = time.time() - t0

    print(f"technique={args.technique} objective={args.objective} "
          f"days={args.days} wall={dt:.1f}s ({dt / args.days * 1e3:.0f} ms/day)")
    print(f"{'day':16s} {'carbon_kg':>12s} {'cost_usd':>12s} {'peak_kw':>10s}")
    for i, name in enumerate(names):
        print(f"{name:16s} {res['day_totals']['carbon_kg'][i]:12.1f} "
              f"{res['day_totals']['cost_usd'][i]:12.1f} "
              f"{res['peak_w'][i].max() / 1e3:10.1f}")
    print(f"{'MONTH':16s} {res['totals']['carbon_kg']:12.1f} "
          f"{res['totals']['cost_usd']:12.1f} "
          f"{res['final_peak_w'].max() / 1e3:10.1f}")


if __name__ == "__main__":
    main()
