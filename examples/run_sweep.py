"""Severity sweeps through the declarative ExperimentSpec API.

One ``sweep`` call expands a wan_degradation × origin_shift grid into a
stacked env batch and runs each technique through ONE batched compile over
every grid point. To produce the routed-vs-source-blind degradation curves,
a second technique — ``fd-blind``, registered here via the public
``register_technique`` hook — solves the source-*blind* (I, D) game each
epoch and broadcasts its split to every source region, so both curves are
priced by the same routed simulator. As the WAN degrades and demand origins
shift east, the source-blind SLA bill blows up while the routed scheduler
keeps requests near their origins.

    PYTHONPATH=src python examples/run_sweep.py
    PYTHONPATH=src python examples/run_sweep.py --hours 12 --factors 1,2,4,8
    PYTHONPATH=src python examples/run_sweep.py --quick      # smoke grid
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax.numpy as jnp

from repro import scenarios as S
from repro.core import ExperimentSpec, register_technique, sweep
from repro.core.force_directed import FDConfig, solve_epoch as fd_solve
from repro.core.game import GameContext, SolveResult
from repro.dcsim import env as E


# both techniques run the SAME solver budget — the curves compare routing
# surfaces, not iteration counts
FD_CFG = FDConfig(iters=60)


def blind_solve(key, ctx, peak_state, cfg=FD_CFG):
    """Source-blind FD: solve the aggregate (I, D) game — one source, mean
    RTT, exactly the PR 3 decision surface — then broadcast the split to
    every source region. The routed engine prices the result per
    (source, task) path, so the comparison against routed FD is fair."""
    agg = GameContext(env=E.aggregate_origin(ctx.env), tau=ctx.tau,
                      objective=ctx.objective, routed=False)
    res = fd_solve(key, agg, peak_state, cfg=cfg)
    fr = jnp.broadcast_to(res.fractions,
                          (ctx.num_sources(),) + res.fractions.shape)
    return SolveResult(fr, res.info)


register_technique("fd-blind", blind_solve, default_cfg=FD_CFG)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dcs", type=int, default=4, choices=(4, 8, 16))
    ap.add_argument("--hours", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--factors", default="1,2,4",
                    help="wan_degradation RTT factors (grid axis 1)")
    ap.add_argument("--weights", default="0.0,0.4,0.8",
                    help="origin_shift east-shift weights (grid axis 2)")
    ap.add_argument("--quick", action="store_true",
                    help="2x2 grid, 6 hours (the `make sweep-smoke` setting)")
    args = ap.parse_args()
    if args.quick:
        args.hours, args.factors, args.weights = 6, "1,3", "0.0,0.8"

    factors = tuple(float(x) for x in args.factors.split(","))
    weights = tuple(float(x) for x in args.weights.split(","))
    grid = {"wan_degradation": factors,
            "origin_shift": tuple({"weight": w, "toward": (0,)}
                                  for w in weights)}
    base = (S.Scenario("sla_tighten", {"tighten": 0.7}),)
    spec = ExperimentSpec(technique="fd", objective="cost_sla",
                          engine="batched", routed=True, hours=args.hours,
                          seed=args.seed, cfg=FD_CFG)

    env = E.build_env(args.dcs, seed=args.seed)
    n_pts = len(factors) * len(weights)
    print(f"sweep: wan_degradation{factors} x origin_shift{weights} "
          f"-> {n_pts} scenario-days, objective=cost_sla routed=True\n")

    t0 = time.time()
    res = sweep(spec, grid, base_env=env, techniques=("fd", "fd-blind"),
                base_scenarios=base)
    wall = time.time() - t0

    sla = {t: res["results"][t]["totals"]["sla_miss_cost_usd"]
           for t in ("fd", "fd-blind")}
    cost = {t: res["results"][t]["totals"]["cost_usd"]
            for t in ("fd", "fd-blind")}
    print(f"{'grid point':42s} {'blind_sla$':>12s} {'routed_sla$':>12s} "
          f"{'cut%':>7s} {'routed_cost$':>13s}")
    for p, lbl in enumerate(res["labels"]):
        b, r = sla["fd-blind"][p], sla["fd"][p]
        cut = 100.0 * (b - r) / max(abs(b), 1e-9)
        print(f"{lbl:42s} {b:12.1f} {r:12.1f} {cut:6.1f}% {cost['fd'][p]:13.1f}")

    # the headline: at the harshest grid point the routed scheduler must
    # beat the source-blind baseline on the SLA bill (it sees origins)
    b, r = sla["fd-blind"][-1], sla["fd"][-1]
    assert r < b, "routed fd must cut the SLA bill at the harshest point"
    print(f"\n{n_pts} grid points x 2 techniques in {wall:.1f}s "
          "(one batched compile each); at "
          f"{res['labels'][-1]}: routed fd cuts the SLA bill "
          f"{100.0 * (b - r) / b:.0f}% vs the source-blind split.")


if __name__ == "__main__":
    main()
