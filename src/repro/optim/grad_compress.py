"""Error-feedback int8 gradient compression for the DP all-reduce.

A distributed-optimization trick for bandwidth-constrained scale-out (the
"pod" axis of the multi-pod mesh crosses DCI links that are ~10× slower than
intra-pod ICI): gradients are quantized to int8 with blockwise absmax scales
before the data-parallel all-reduce, and the quantization error is carried
to the next step (error feedback keeps SGD/Adam convergence).

Implemented with shard_map so the collective and the quantization are
explicit: psum(int8→f32) costs 1/4 the bytes of a bf16 all-reduce on the
wire when the reduction is hierarchical (intra-pod first, compressed across
pods). On the CPU container this is validated for correctness (tests) and
is flag-gated off by default in the train step.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .adamw import _dequantize, _quantize


def compress_decompress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize→dequantize one tensor; returns (approx, error)."""
    q, s = _quantize(g.astype(jnp.float32))
    approx = _dequantize(q, s, g.shape)
    return approx.astype(g.dtype), (g.astype(jnp.float32) - approx).astype(g.dtype)


def ef_compress_tree(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Error-feedback compression over a grad pytree.

    grads_compensated = grads + carried_error; returns (approx, new_error).
    """
    comp = jax.tree_util.tree_map(lambda g, e: g + e.astype(g.dtype), grads, error)
    out = jax.tree_util.tree_map(compress_decompress, comp)
    approx = jax.tree_util.tree_map(lambda t: t[0], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return approx, err


def init_error(grads_shape: Any) -> Any:
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), grads_shape)


def compressed_psum(x: jnp.ndarray, mesh: Mesh, axis: str = "pod") -> jnp.ndarray:
    """Quantized all-reduce over one mesh axis via shard_map.

    Each shard quantizes its local contribution; the psum runs on the
    dequantized values (XLA reduces over the wire in the compressed layout
    on TPU via int8 allreduce when available; semantically this matches
    quantize→reduce→dequantize up to the blockwise scales).
    """
    if axis not in mesh.axis_names:
        return x

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(*([None] * x.ndim)),
        out_specs=P(*([None] * x.ndim)),
    )
    def _inner(xl):
        q, s = _quantize(xl.astype(jnp.float32))
        approx = _dequantize(q, s, xl.shape)
        return jax.lax.psum(approx, axis) / 1.0

    return _inner(x)
