"""AdamW over pytrees, with optional 8-bit quantized moments.

The 8-bit option (blockwise absmax int8, error-free requantization each
step) is what lets arctic-480b train on a single 256-chip pod: bf16 params
(0.96 TB) + two int8 moment trees (0.96 TB) ≈ 7.5 GB/chip instead of the
18.8 GB/chip that fp32 moments would need. The moment trees inherit the
parameter PartitionSpecs, so FSDP shards them too.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any

QBLOCK = 256  # absmax quantization block (flattened)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False
    moment_dtype: str = "float32"  # float32 | bfloat16 (arctic-480b on 1 pod)


# ---------------------------------------------------------------------------
# int8 blockwise quantization
# ---------------------------------------------------------------------------

def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


class _QTensor(NamedTuple):
    q: jnp.ndarray
    scale: jnp.ndarray


def _moment_init(p: jnp.ndarray, quant: bool, dtype=jnp.float32):
    if quant:
        q, s = _quantize(jnp.zeros(p.shape, jnp.float32))
        return _QTensor(q, s)
    return jnp.zeros(p.shape, dtype)


def _moment_read(m, shape):
    if isinstance(m, _QTensor):
        return _dequantize(m.q, m.scale, shape)
    return m


def _moment_write(val: jnp.ndarray, quant: bool):
    if quant:
        return _QTensor(*_quantize(val))
    return val


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params: Params, cfg: AdamWConfig) -> OptState:
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]
    mk = lambda p: _moment_init(p, cfg.quantize_moments, mdt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(mk, params),
        nu=jax.tree_util.tree_map(mk, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Params,
    state: OptState,
    params: Params,
    cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
) -> Tuple[Params, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip > 0 else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    is_q = lambda x: isinstance(x, _QTensor)

    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        m = b1 * _moment_read(mu, p.shape) + (1 - b1) * g
        v = b2 * _moment_read(nu, p.shape) + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.quantize_moments:
            return new_p, _moment_write(m, True), _moment_write(v, True)
        return new_p, m.astype(mdt), v.astype(mdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = jax.tree_util.tree_leaves(state.mu, is_leaf=is_q)
    flat_nu = jax.tree_util.tree_leaves(state.nu, is_leaf=is_q)
    out = [upd(p, g, mu, nu) for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr * jnp.ones(())}
    return new_params, OptState(step, new_mu, new_nu), metrics
