"""Geo-distributed data-center topology: locations, node types, task types.

Faithful to the paper's simulation environment (§6): 4/8/16 DC configs over
continental-US cities with an even east/west split; each DC has 4,320 nodes
in four aisles drawn from three Xeon node types; ten AIBench-derived task
types. The raw measurement tables of [16]/[37] are unpublished, so the
numeric tables here are synthetic-but-shaped: magnitudes match the cited
hardware (Xeon TDPs, AIBench-class inference latencies) and all relative
structure (memory-intensity classes, heterogeneous speeds) is preserved.
A TPU-v5e node type is included as the beyond-paper bridge to the serving
substrate (execution rates derived from the roofline analysis).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Node types (paper §6: Intel Xeon E3-1225v3, E5649, E5-2697v2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeType:
    name: str
    cores: int
    idle_w: float     # package idle power, W
    peak_dyn_w: float  # peak dynamic power (all cores), W
    ghz: float


NODE_TYPES: Tuple[NodeType, ...] = (
    NodeType("xeon-e3-1225v3", 4, 18.0, 66.0, 3.2),
    NodeType("xeon-e5649", 6, 35.0, 80.0, 2.53),
    NodeType("xeon-e5-2697v2", 12, 45.0, 130.0, 2.7),
    # beyond-paper accelerator node (execution rates filled from roofline)
    NodeType("tpu-v5e-host", 4, 120.0, 400.0, 0.0),
)
NUM_XEON_TYPES = 3

# ---------------------------------------------------------------------------
# Task types (paper Table 2: AIBench inference workloads)
# columns: name, mem-intensity class (0 low,1 med,2 high), size GB,
#          base exec time (s) on the three Xeon types
# ---------------------------------------------------------------------------

TASK_TYPES: Tuple[Tuple[str, int, float, Tuple[float, float, float]], ...] = (
    ("image-classification", 1, 0.30, (0.08, 0.12, 0.05)),
    ("image-generation", 2, 0.80, (1.90, 2.60, 1.20)),
    ("image-to-text", 1, 0.45, (0.55, 0.80, 0.35)),
    ("image-to-image", 2, 0.90, (2.30, 3.10, 1.50)),
    ("speech-recognition", 1, 0.60, (0.70, 1.00, 0.45)),
    ("face-embedding", 0, 0.25, (0.06, 0.09, 0.04)),
    ("face-recognition-3d", 1, 0.55, (0.90, 1.30, 0.60)),
    ("video-prediction", 2, 1.20, (2.80, 3.90, 1.80)),
    ("image-compression", 1, 0.40, (0.50, 0.75, 0.32)),
    ("object-reconstruction-3d", 2, 1.00, (2.10, 2.90, 1.40)),
)

NUM_TASK_TYPES = len(TASK_TYPES)

# ---------------------------------------------------------------------------
# Locations: (city, state, tz offset h vs UTC, carbon factor kgCO2/kWh
#             [EIA-shaped], TOU base $/kWh, peak demand $/kW, net metering α,
#             solar capacity factor, wind capacity factor, lat °N, lon °E)
# The trailing (lat, lon) pair feeds the inter-region RTT matrix of the
# SLA/latency model (``dcsim.latency.rtt_matrix``).
# ---------------------------------------------------------------------------

LOCATIONS: Tuple[Tuple[str, str, int, float, float, float, float, float, float, float, float], ...] = (
    ("new-york", "NY", -5, 0.23, 0.180, 18.0, 1.00, 0.35, 0.25, 40.71, -74.01),
    ("san-francisco", "CA", -8, 0.21, 0.220, 20.0, 1.00, 0.65, 0.40, 37.77, -122.42),
    ("chicago", "IL", -6, 0.43, 0.120, 14.0, 1.00, 0.40, 0.55, 41.88, -87.63),
    ("dallas", "TX", -6, 0.41, 0.095, 11.0, 0.75, 0.60, 0.85, 32.78, -96.80),
    ("seattle", "WA", -8, 0.09, 0.090, 10.0, 1.00, 0.30, 0.45, 47.61, -122.33),
    ("miami", "FL", -5, 0.39, 0.110, 12.0, 0.50, 0.60, 0.20, 25.76, -80.19),
    ("denver", "CO", -7, 0.55, 0.115, 13.0, 1.00, 0.70, 0.75, 39.74, -104.99),
    ("boston", "MA", -5, 0.31, 0.210, 19.0, 1.00, 0.35, 0.35, 42.36, -71.06),
    ("phoenix", "AZ", -7, 0.37, 0.105, 12.5, 0.70, 0.85, 0.30, 33.45, -112.07),
    ("atlanta", "GA", -5, 0.40, 0.100, 11.5, 0.00, 0.50, 0.20, 33.75, -84.39),
    ("portland", "OR", -8, 0.12, 0.095, 10.5, 1.00, 0.35, 0.50, 45.52, -122.68),
    ("columbus", "OH", -5, 0.52, 0.115, 13.5, 1.00, 0.38, 0.40, 39.96, -83.00),
    ("salt-lake-city", "UT", -7, 0.58, 0.098, 11.0, 0.85, 0.75, 0.55, 40.76, -111.89),
    ("kansas-city", "MO", -6, 0.60, 0.100, 12.0, 1.00, 0.48, 0.70, 39.10, -94.58),
    ("las-vegas", "NV", -8, 0.34, 0.102, 12.0, 0.90, 0.88, 0.35, 36.17, -115.14),
    ("charlotte", "NC", -5, 0.33, 0.098, 11.0, 0.00, 0.52, 0.22, 35.23, -80.84),
)


# named column accessors for LOCATIONS rows — downstream code must not index
# the tuple by magic position (a schema change would silently corrupt, e.g.,
# the RTT matrix built from the trailing coordinate pair)
LOC_LAT, LOC_LON = 9, 10


def location_coords(loc_indices=None) -> Tuple[np.ndarray, np.ndarray]:
    """(lat °N, lon °E) arrays for the given LOCATIONS rows (default: all).

    The single named accessor for the coordinate columns; the geometry
    regression test pins a known city-pair RTT through it, so a LOCATIONS
    schema change breaks loudly instead of silently skewing distances.
    """
    rows = (LOCATIONS if loc_indices is None
            else [LOCATIONS[i] for i in loc_indices])
    lat = np.array([r[LOC_LAT] for r in rows], dtype=float)
    lon = np.array([r[LOC_LON] for r in rows], dtype=float)
    return lat, lon


def dc_locations(num_dcs: int) -> List[int]:
    """Pick an even east/west coast mix as the paper does (Fig. 5)."""
    assert num_dcs in (4, 8, 16), num_dcs
    if num_dcs == 4:
        return [0, 1, 3, 4]  # NY, SF, Dallas, Seattle
    if num_dcs == 8:
        return [0, 1, 2, 3, 4, 5, 6, 7]
    return list(range(16))


NODES_PER_DC = 4320  # paper §6
AISLES_PER_DC = 4
CRAC_PER_DC = 4         # CRAC units per DC  # lint: unit(1)
CRAC_MAX_W = 120_000.0  # per CRAC unit rating  # lint: unit(W)
NETWORK_PRICE = 0.085   # AWS CloudFront-shaped  # lint: unit(USD/GB)


# ---------------------------------------------------------------------------
# Accelerator fleet (beyond-paper, the token-grounded "llm" workload model).
# Each accelerator node is one host of ``chips`` chips; per-chip peak compute,
# HBM bandwidth/capacity, and interconnect bandwidth are expressed relative to
# the measured TPU-v5e roofline constants in ``launch/roofline.py`` so the
# capability layer's derived tokens/sec stay anchored to the same hardware
# model the roofline analyzer uses. idle_w/dyn_w are hardware-spec node power
# draws (the one kind of constant the llm path is allowed: hardware, never
# per-task execution times).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AccelType:
    """One accelerator node type: per-chip roofline specs + node power.

    Machine-read unit table (repro.lint.units):

        name: -
        chips: chip/node
        peak_flops: FLOP/s
        hbm_bw: B/s
        hbm_gb: GiB
        ici_bw: B/s
        idle_w: W
        dyn_w: W
    """
    name: str
    chips: int          # chips per node (host)
    peak_flops: float   # per chip, bf16 FLOP/s
    hbm_bw: float       # per chip, bytes/s
    hbm_gb: float       # per chip, GiB of HBM
    ici_bw: float       # per chip, interconnect bytes/s
    idle_w: float       # node idle power, W
    dyn_w: float        # node peak dynamic power, W


def _accel_types() -> Tuple[AccelType, ...]:
    from ..launch import roofline as R  # namespace pkg, constants only

    return (
        # previous generation: weaker compute, more HBM per chip
        AccelType("tpu-gen-a", 4, 0.70 * R.PEAK_FLOPS, 0.75 * R.HBM_BW,
                  32.0, 0.90 * R.ICI_BW, 140.0, 1000.0),
        # the roofline-measured v5e-class host (1x by construction)
        AccelType("tpu-gen-b", 4, 1.00 * R.PEAK_FLOPS, 1.00 * R.HBM_BW,
                  16.0, 1.00 * R.ICI_BW, 120.0, 1100.0),
        # large-model generation: big HBM, fat interconnect
        AccelType("tpu-gen-c", 4, 2.33 * R.PEAK_FLOPS, 3.35 * R.HBM_BW,
                  95.0, 2.00 * R.ICI_BW, 220.0, 2600.0),
    )


ACCEL_TYPES: Tuple[AccelType, ...] = _accel_types()

# one accelerator aisle's worth of hosts per DC (mirrors the include_tpu
# carve-out in ``node_mix``)
ACCEL_NODES_PER_DC = NODES_PER_DC // AISLES_PER_DC


def accel_mix(seed: int, num_dcs: int,
              num_accel_types: int | None = None,
              nodes_per_dc: int = ACCEL_NODES_PER_DC) -> np.ndarray:
    """NN[d, a]: accelerator node counts per DC, rows sum to ``nodes_per_dc``.

    Mirrors ``node_mix``'s heterogeneity story for the accelerator fleet:
    most DCs run a dirichlet blend of generations; every 3rd DC is a
    single-generation fleet (procurement waves are lumpy). Seeded off a
    distinct stream (``seed + 101``) so the Xeon and accelerator mixes of
    one scenario seed are independent draws.
    """
    if num_accel_types is None:
        num_accel_types = len(ACCEL_TYPES)
    rng = np.random.default_rng(seed + 101)
    out = np.zeros((num_dcs, num_accel_types), np.int64)
    for d in range(num_dcs):
        if d % 3 == 2 and num_accel_types > 1:  # single-generation fleet
            out[d, int(rng.integers(num_accel_types))] = nodes_per_dc
            continue
        w = rng.dirichlet(np.ones(num_accel_types) * 2.0)
        for a in range(num_accel_types):
            out[d, a] = int(round(w[a] * nodes_per_dc))
        out[d] = _fix_sum(out[d], nodes_per_dc)
    return out


def node_mix(seed: int, num_dcs: int, include_tpu: bool = False) -> np.ndarray:
    """NN[d, j]: heterogeneous node counts per DC, rows sum to 4320.

    'most locations having three node types', some with two (paper §6).
    """
    rng = np.random.default_rng(seed)
    jn = NUM_XEON_TYPES + (1 if include_tpu else 0)
    out = np.zeros((num_dcs, jn), np.int64)
    for d in range(num_dcs):
        if d % 4 == 3:  # every 4th DC has two node types
            w = rng.dirichlet(np.ones(2) * 4.0)
            types = rng.choice(NUM_XEON_TYPES, 2, replace=False)
            for t, wi in zip(types, w):
                out[d, t] = int(round(wi * NODES_PER_DC))
        else:
            w = rng.dirichlet(np.ones(NUM_XEON_TYPES) * 4.0)
            for t in range(NUM_XEON_TYPES):
                out[d, t] = int(round(w[t] * NODES_PER_DC))
        if include_tpu:
            # carve out a TPU aisle (beyond-paper)
            out[d, -1] = NODES_PER_DC // AISLES_PER_DC
        out[d, : NUM_XEON_TYPES] = _fix_sum(out[d, : NUM_XEON_TYPES], NODES_PER_DC - out[d, -1] if include_tpu else NODES_PER_DC)
    return out


def _fix_sum(row: np.ndarray, want: int) -> np.ndarray:
    diff = want - row.sum()
    j = int(np.argmax(row))
    row[j] += diff
    return row
