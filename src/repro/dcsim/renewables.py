"""Renewable power generation: diurnal solar + stochastic wind, per location.

NSRDB-shaped procedural generators (seeded, documented): solar follows a
clipped cosine of local solar hour scaled by a monthly insolation factor;
wind is a seeded AR(1) process around each site's capacity factor. Units
are watts of on-site generation per data center.
"""
from __future__ import annotations

import numpy as np

# monthly insolation scale (northern hemisphere, Jun=1.0)
MONTH_SOLAR = np.array([0.55, 0.62, 0.75, 0.85, 0.95, 1.00, 0.98, 0.92, 0.82, 0.70, 0.58, 0.52])
MONTH_WIND = np.array([1.10, 1.08, 1.05, 1.00, 0.92, 0.85, 0.82, 0.85, 0.92, 1.00, 1.06, 1.10])


def renewable_profile(
    tz_offsets: np.ndarray,      # (D,) hours vs UTC
    solar_cap: np.ndarray,       # (D,) capacity factors 0..1
    wind_cap: np.ndarray,        # (D,)
    installed_w: float,          # nameplate W per DC
    month: int,                  # 1..12
    seed: int,
) -> np.ndarray:
    """RP[d, 24] watts available at each UTC hour of a representative day."""
    d = len(tz_offsets)
    rng = np.random.default_rng(seed * 100 + month)
    hours = np.arange(24)
    rp = np.zeros((d, 24))
    for i in range(d):
        local = (hours + tz_offsets[i]) % 24
        # solar: cosine bump centered at 13:00 local, ~7h half-width
        ang = (local - 13.0) / 7.0 * (np.pi / 2)
        solar = np.clip(np.cos(ang), 0.0, None) ** 1.3
        solar *= solar_cap[i] * MONTH_SOLAR[month - 1]
        # wind: AR(1) around site capacity, mildly nocturnal
        w = np.zeros(24)
        x = 0.0
        for h in range(24):
            x = 0.7 * x + 0.3 * rng.normal(0.0, 0.35)
            w[h] = x
        wind = np.clip(wind_cap[i] * MONTH_WIND[month - 1] * (1.0 + w + 0.15 * np.cos((local - 2) / 24 * 2 * np.pi)), 0.0, 1.2)
        rp[i] = installed_w * (0.6 * solar + 0.4 * wind)
    return rp
