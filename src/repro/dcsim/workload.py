"""Workload arrival-rate patterns (paper §6, Fig. 6).

Sinusoidal (consumer-interactive) and flat (continuous-compute) cloud-level
arrival rates per task type, plus the per-run normal resampling the paper
uses (mean = pattern value, std = 20% of mean).
"""
from __future__ import annotations

import numpy as np

from .topology import NUM_TASK_TYPES


def base_rates(num_dcs: int, utilization: float = 0.45) -> np.ndarray:
    """Peak cloud arrival rate per task type (tasks/hour).

    Scaled so that at the daily peak the cloud runs at roughly
    ``utilization`` of aggregate capacity (the paper's under-subscribed
    regime) — the env builder rescales against actual capacity.
    """
    rng = np.random.default_rng(1234)
    w = rng.dirichlet(np.ones(NUM_TASK_TYPES) * 3.0)
    return w * utilization * num_dcs


def arrival_pattern(
    kind: str,           # "sinusoidal" | "flat"
    base: np.ndarray,    # (I,) peak rates
    seed: int = 0,
    resample: bool = True,
) -> np.ndarray:
    """CAR[i, 24]: cloud arrival rate per task type per UTC hour."""
    i = base.shape[0]
    hours = np.arange(24)
    if kind == "sinusoidal":
        # consumer diurnal: trough ~6 AM, peak ~8 PM UTC (paper Fig. 6 shape)
        shape = 0.65 + 0.35 * np.sin((hours - 14.0) / 24.0 * 2 * np.pi)
    elif kind == "flat":
        shape = np.full(24, 0.82)
    else:  # pragma: no cover
        raise ValueError(kind)
    car = base[:, None] * shape[None, :]
    if resample:
        rng = np.random.default_rng(seed)
        car = np.clip(rng.normal(car, 0.2 * car), 0.05 * car, None)
    return car
