"""Workload arrival-rate patterns (paper §6, Fig. 6) — the single source of
truth for cloud arrival-rate construction.

Patterns: sinusoidal (consumer-interactive), flat (continuous-compute), plus
the beyond-paper shapes used by the scenario engine (`repro.scenarios`):
weekday (double-hump business hours), weekend (late, lower peak) and bursty
(flat base with seeded spike trains). Per-run normal resampling follows the
paper (mean = pattern value, std = 20% of mean).

``build_env`` and every scenario transform route through ``base_rates`` /
``arrival_pattern`` so arrival construction is never re-implemented inline.
"""
from __future__ import annotations

import numpy as np

PATTERNS = ("sinusoidal", "flat", "weekday", "weekend", "bursty")


def base_rates(
    capacity: np.ndarray,
    utilization: float = 0.45,
    *,
    concentration: float = 3.0,
    weight_seed: int = 1234,
) -> np.ndarray:
    """Peak cloud arrival rate per task type (tasks/hour).

    ``capacity`` is the aggregate per-type execution rate ER.sum(axis=1),
    shape (I,). Each type gets a Dirichlet share w_i (Σw=1, fixed
    ``weight_seed`` so the task mix is infrastructure-stable across runs)
    of its own capacity × ``utilization``, so total utilization
    Σ_i CAR_i/cap_i peaks near ``utilization`` (the paper's
    under-subscribed regime).
    """
    capacity = np.asarray(capacity, dtype=float)
    rng = np.random.default_rng(weight_seed)
    w = rng.dirichlet(np.ones(capacity.shape[0]) * concentration)
    return utilization * w * capacity


def arrival_pattern(
    kind: str,           # one of PATTERNS
    base: np.ndarray,    # (I,) peak rates
    seed: int = 0,
    resample: bool = True,
) -> np.ndarray:
    """CAR[i, 24]: cloud arrival rate per task type per UTC hour."""
    i = base.shape[0]
    hours = np.arange(24)
    if kind == "sinusoidal":
        # consumer diurnal: trough ~6 AM, peak ~8 PM UTC (paper Fig. 6 shape)
        shape = 0.65 + 0.35 * np.sin((hours - 14.0) / 24.0 * 2 * np.pi)
    elif kind == "flat":
        shape = np.full(24, 0.82)
    elif kind == "weekday":
        # business double-hump: morning and afternoon peaks, lunch dip
        am = np.exp(-0.5 * ((hours - 15.0) / 2.2) ** 2)
        pm = np.exp(-0.5 * ((hours - 21.0) / 2.6) ** 2)
        shape = 0.40 + 0.55 * np.maximum(am, pm)
    elif kind == "weekend":
        # later, flatter leisure peak at ~60% weekday volume
        shape = 0.35 + 0.25 * np.sin((hours - 17.0) / 24.0 * 2 * np.pi)
    elif kind == "bursty":
        # low base + a seeded train of short 2-3.3x spikes (flash-crowd-like);
        # spike windows never overlap — overlapping draws used to multiply
        # magnitudes into the np.minimum cap, flattening the documented
        # 2-3.3x bursts into clipped plateaus — so every spiked hour carries
        # exactly one magnitude and stays inside capacity headroom
        rng = np.random.default_rng(seed + 7331)
        shape = np.full(24, 0.30)
        occupied = np.zeros(24, dtype=bool)
        want = int(rng.integers(2, 5))
        placed = attempts = 0
        while placed < want and attempts < 8 * want:
            attempts += 1
            t0 = int(rng.integers(0, 24))
            width = int(rng.integers(1, 4))
            window = [(t0 + k) % 24 for k in range(width)]
            if occupied[window].any():
                continue
            shape[window] *= float(rng.uniform(2.0, 3.3))
            occupied[window] = True
            placed += 1
        shape = np.minimum(shape, 1.0)  # safety net; never binds at base 0.30
    else:  # pragma: no cover
        raise ValueError(f"unknown arrival pattern {kind!r}; known: {PATTERNS}")
    car = base[:, None] * shape[None, :]
    if resample:
        car = resample_car(car, seed)
    return car


def resample_car(car: np.ndarray, seed: int, std: float = 0.2) -> np.ndarray:
    """The paper's per-run variation: CAR ~ N(CAR, std·CAR), floored at 5%."""
    rng = np.random.default_rng(seed)
    return np.clip(rng.normal(car, std * car), 0.05 * car, None)
