"""SLA/latency model: M/M/c-style queueing delay + WAN RTT + miss pricing.

The paper's schedulers trade carbon against cost with no performance term at
all — nothing stops them from piling every task onto the cheapest/greenest
DC. DCcluster-Opt (arXiv:2511.00117) and Green-LLM (arXiv:2507.09942) make
queueing delay and SLA violations first-class objective terms for exactly
this workload; this module is that subsystem for the repro.

Three pure, jittable pieces (plain array math — no EnvParams import, so
``dcsim.env`` can layer its latency/SLA accessors on top without a cycle):

- **Network**: an inter-region RTT matrix from the great-circle distances of
  ``topology.location_coords()`` (fiber speed ≈ c/1.5, a path-stretch
  factor, per-direction hop overhead). The canonical representation is the
  full (D, D) matrix (row = source region); ``access_ms`` collapses it to
  the (D,) uniform-origin mean for the unrouted model, while the routed
  model (``expected_latency_ms_routed``) keeps the per-path values so the
  (source → DC) split is a real decision surface.
- **Queueing**: each DC is an M/M/c-style station whose c = NN_d nodes
  jointly serve at ER[i, d] tasks/h. The per-task service share is
  ``s_ms[i, d] = 3.6e6 · NN_d / ER[i, d]`` (node-internal core parallelism
  is already folded into ER) and the expected sojourn scales it by the
  processor-sharing factor ``1 / (1 - rho)``, with utilization clipped at
  ``RHO_MAX`` so saturated hours stay finite and differentiable. ``avail``
  curtailment cancels out of s_ms (nodes and rate shrink together) and
  enters through rho, which is computed against effective capacity.
- **SLA pricing**: a smooth miss probability ``sigmoid((lat - sla) /
  (SLA_SOFTNESS · sla))`` (a differentiable stand-in for the M/M/c waiting
  tail) priced per task: ``$ / h = sla_price · AR · p_miss``. With the
  paper-default ``sla_price = 0`` every term below is exactly zero.

Units: latencies/SLAs ms, rates tasks/h, distances km, prices $/task.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import topology
from ..units import MS_PER_H

EARTH_RADIUS_KM = 6371.0    # lint: unit(km)
FIBER_KM_PER_MS = 200.0    # signal speed in glass ≈ c/1.5  # lint: unit(km/ms)
PATH_STRETCH = 1.4         # real fiber routes vs the great circle
HOP_OVERHEAD_MS = 2.0      # serialization + routing + handoff  # lint: unit(ms)
RHO_MAX = 0.995            # queueing-factor utilization clip (keeps 1/(1-ρ) finite)
SLA_SOFTNESS = 0.1         # sigmoid width as a fraction of the SLA target
SLA_MARGIN = 4.0           # default SLA = margin × fleet-mean zero-load latency

_EPS = 1e-9


# ---------------------------------------------------------------------------
# network: inter-region RTT from LOCATIONS coordinates
# ---------------------------------------------------------------------------

def haversine_km(lat: np.ndarray, lon: np.ndarray) -> np.ndarray:
    """(D, D) great-circle distances for degree coordinate vectors."""
    la, lo = np.radians(np.asarray(lat, float)), np.radians(np.asarray(lon, float))
    dla = la[:, None] - la[None, :]
    dlo = lo[:, None] - lo[None, :]
    h = (np.sin(dla / 2.0) ** 2
         + np.cos(la)[:, None] * np.cos(la)[None, :] * np.sin(dlo / 2.0) ** 2)
    return 2.0 * EARTH_RADIUS_KM * np.arcsin(np.sqrt(np.clip(h, 0.0, 1.0)))


def rtt_matrix(loc_indices: Optional[Sequence[int]] = None, *,
               num_dcs: Optional[int] = None) -> np.ndarray:
    """(D, D) round-trip times (ms) between DC regions.

    ``loc_indices`` rows into ``topology.LOCATIONS``; ``num_dcs`` instead
    picks the paper's east/west mix via ``topology.dc_locations`` (falling
    back to the first D rows for non-standard fleet sizes). The diagonal is
    intra-region: no propagation, no hop overhead.
    """
    if loc_indices is None:
        assert num_dcs is not None, "need loc_indices or num_dcs"
        loc_indices = (topology.dc_locations(num_dcs) if num_dcs in (4, 8, 16)
                       else list(range(num_dcs)))
    lat, lon = topology.location_coords(loc_indices)
    dist = haversine_km(lat, lon)
    rtt = 2.0 * (dist * PATH_STRETCH / FIBER_KM_PER_MS + HOP_OVERHEAD_MS)
    np.fill_diagonal(rtt, 0.0)
    return rtt


def access_ms(rtt: jnp.ndarray) -> jnp.ndarray:
    """(D,) mean access RTT over uniform request origins.

    ``rtt`` must be the canonical (D, D) matrix (axis 0 = source region);
    the old (D,)-vector alternate representation is gone — per-path values
    are needed by the routed model, and the dual shape bred special cases
    (``wan_degradation``'s scalar cross-path factor mispriced ``extra_ms``).
    """
    rtt = jnp.asarray(rtt)
    if rtt.ndim != 2:
        raise ValueError(
            f"rtt must be the canonical (D, D) matrix, got shape {rtt.shape}")
    return jnp.mean(rtt, axis=0)


# ---------------------------------------------------------------------------
# queueing: M/M/c-style sojourn per (task, DC)
# ---------------------------------------------------------------------------

def service_ms(er: jnp.ndarray, nn_total: jnp.ndarray) -> jnp.ndarray:
    """(I, D) zero-load service share per task: 3.6e6 · NN_d / ER[i, d]."""
    # ms/h * node / (task/h) reads as ms per request: node is a
    # dimensionless server count in the M/M/c convention
    return MS_PER_H * nn_total[None, :] / jnp.maximum(er, _EPS)  # lint: unit-ok(node is a dimensionless server count)


def queue_factor(rho: jnp.ndarray) -> jnp.ndarray:
    """Processor-sharing delay factor 1 / (1 - ρ), clipped at RHO_MAX.

    Monotone non-decreasing in ρ, equal to 1 at ρ = 0.
    """
    return 1.0 / (1.0 - jnp.clip(rho, 0.0, RHO_MAX))


def expected_latency_ms(er: jnp.ndarray, nn_total: jnp.ndarray,
                        rho: jnp.ndarray, rtt: jnp.ndarray) -> jnp.ndarray:
    """(I, D) expected response time: access RTT + queued service sojourn."""
    return access_ms(rtt)[None, :] + service_ms(er, nn_total) * queue_factor(rho)[None, :]


def expected_latency_ms_routed(er: jnp.ndarray, nn_total: jnp.ndarray,
                               rho: jnp.ndarray,
                               src_rtt: jnp.ndarray) -> jnp.ndarray:
    """(S, I, D) per-path response time: ``src_rtt[s, d]`` + queued sojourn.

    ``src_rtt`` is the (S, D) source-region → DC round trip (``rtt`` itself
    when sources are the DC regions; its uniform-origin row mean when S = 1,
    the degenerate aggregate source that reproduces ``expected_latency_ms``
    bit-for-bit). The queued sojourn is source-independent — requests queue
    at the serving DC — so it broadcasts over the source axis.
    """
    sojourn = service_ms(er, nn_total) * queue_factor(rho)[None, :]
    return src_rtt[:, None, :] + sojourn[None, :, :]


# ---------------------------------------------------------------------------
# SLA pricing
# ---------------------------------------------------------------------------

def sla_miss_prob(lat_ms: jnp.ndarray, sla_ms: jnp.ndarray) -> jnp.ndarray:
    """Smooth miss probability: sigmoid((lat - sla) / (SLA_SOFTNESS · sla))."""
    width = SLA_SOFTNESS * jnp.maximum(sla_ms, _EPS)
    return jax.nn.sigmoid((lat_ms - sla_ms) / width)


def default_sla_ms(er: np.ndarray, nn_total: np.ndarray,
                   margin: float = SLA_MARGIN) -> np.ndarray:
    """(I,) canonical per-task SLA target: ``margin`` × the capacity-weighted
    fleet mean of the zero-load latency. Comfortably slack at the paper's
    ≤60% utilization, so default envs (sla_price = 0 anyway) never bind."""
    er = np.asarray(er, float)
    nn_total = np.asarray(nn_total, float)
    s = MS_PER_H * nn_total[None, :] / np.maximum(er, _EPS)
    w = er / np.maximum(er.sum(axis=1, keepdims=True), _EPS)
    return margin * (s * w).sum(axis=1)  # lint: unit-ok(node count is dimensionless, as in service_ms)
