"""The geo-distributed cloud environment: paper eqs. (1)–(18) in JAX.

Everything is a pure function of an ``EnvParams`` NamedTuple of jnp arrays,
so objectives are jittable, vmappable (batched game episodes, and the
scenario engine's ``run_days_batched`` fleet evaluation) and differentiable
(the NASH best-reply baseline exploits the gradients).

Shapes: I task types × D data centers × 24 UTC hours.
Units: power W, energy cost $/h (prices $/kWh applied to W/1000),
carbon kg/h, rates tasks/hour.

Beyond-paper extensions for the scenario engine (``repro.scenarios``):
``carbon`` carries an hourly axis (D, 24) so grid carbon-intensity events
(spikes, diurnal marginal-carbon shapes) are expressible, and ``avail``
(D, 24) masks per-DC capacity over the day (outages, demand-response
curtailment). With ``avail == 1`` and a constant carbon profile the model
reduces exactly to the paper's.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import colocation, power, renewables, topology, workload
from .topology import CRAC_MAX_W, CRAC_PER_DC, NETWORK_PRICE, NODES_PER_DC


class EnvParams(NamedTuple):
    er: jnp.ndarray          # (I, D) max execution rate, tasks/h (eq. 3)
    it_idle: jnp.ndarray     # (D,) W
    it_dyn: jnp.ndarray      # (D,) W at full utilization
    tsupply: jnp.ndarray     # (D,) CRAC supply temperature °C
    eff: jnp.ndarray         # (D,) PSU overhead ≥ 1
    rp: jnp.ndarray          # (D, 24) renewable W
    carbon: jnp.ndarray      # (D, 24) kg CO2 / kWh (hourly grid intensity)
    eprice: jnp.ndarray      # (D, 24) $/kWh TOU
    peak_price: jnp.ndarray  # (D,) $/kW-month
    alpha: jnp.ndarray       # (D,) net metering fraction
    nprice: jnp.ndarray      # scalar $/GB
    sizes: jnp.ndarray       # (I,) GB per task
    nn_total: jnp.ndarray    # (D,) node count
    car: jnp.ndarray         # (I, 24) cloud arrival rates
    avail: jnp.ndarray       # (D, 24) capacity availability in [0, 1]


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def build_env(
    num_dcs: int = 4,
    *,
    month: int = 6,
    pattern: str = "sinusoidal",
    seed: int = 0,
    utilization: float = 0.45,
    include_tpu: bool = False,
    renewable_scale: float = 0.8,
) -> EnvParams:
    locs = topology.dc_locations(num_dcs)
    loc_rows = [topology.LOCATIONS[i] for i in locs]
    nn = topology.node_mix(seed, num_dcs, include_tpu=include_tpu)
    er = colocation.er_table(nn)  # (I, D) tasks/h

    idle, dyn = power.node_power_arrays(nn.shape[1])
    it_idle = nn @ idle
    it_dyn = nn @ dyn
    rng = np.random.default_rng(seed + 17)
    tsupply = rng.uniform(16.0, 24.0, num_dcs)
    eff = rng.uniform(1.10, 1.25, num_dcs)

    tz = np.array([r[2] for r in loc_rows])
    carbon = np.array([r[3] for r in loc_rows])
    base_price = np.array([r[4] for r in loc_rows])
    peak_price = np.array([r[5] for r in loc_rows])
    alpha = np.array([r[6] for r in loc_rows])
    solar_cap = np.array([r[7] for r in loc_rows])
    wind_cap = np.array([r[8] for r in loc_rows])

    # TOU profile: peak window 2–8 PM local at 1.7×, shoulder 1.2×, off 0.8×
    hours = np.arange(24)
    eprice = np.zeros((num_dcs, 24))
    for d in range(num_dcs):
        local = (hours + tz[d]) % 24
        mult = np.where((local >= 14) & (local < 20), 1.7,
                        np.where((local >= 8) & (local < 14), 1.2, 0.8))
        eprice[d] = base_price[d] * mult

    installed = renewable_scale * (it_idle + 0.5 * it_dyn)
    rp = renewables.renewable_profile(tz, solar_cap, wind_cap, 1.0, month, seed)
    rp = rp * installed[:, None]

    sizes = np.array([t[2] for t in topology.TASK_TYPES])
    # peak rate per type via workload.base_rates (one source of truth for the
    # Dirichlet task mix): w_i (Σw=1) of its own capacity × utilization, so
    # total utilization Σ_i CAR_i/cap_i peaks near ``utilization``.
    base = workload.base_rates(np.asarray(er).sum(axis=1), utilization)
    car = workload.arrival_pattern(pattern, base, seed=seed)

    f = jnp.asarray
    return EnvParams(
        er=f(er), it_idle=f(it_idle), it_dyn=f(it_dyn), tsupply=f(tsupply),
        eff=f(eff), rp=f(rp), carbon=f(np.tile(carbon[:, None], (1, 24))),
        eprice=f(eprice), peak_price=f(peak_price), alpha=f(alpha),
        nprice=jnp.float32(NETWORK_PRICE), sizes=f(sizes),
        nn_total=f(nn.sum(axis=1).astype(float)), car=f(car),
        avail=jnp.ones((num_dcs, 24)),
    )


def stack_envs(envs) -> EnvParams:
    """Stack same-shape envs leaf-wise into one batched EnvParams.

    The leading axis is a scenario-day (or calendar-day) batch: vmap over it
    for fleet evaluation (``schedulers.run_days_batched``) or scan over it
    for month-scale episodes (``schedulers.run_month``).
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *list(envs))


def tile_env(env: EnvParams, n: int) -> EnvParams:
    """Broadcast one env to a leading axis of ``n`` identical days."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), env)


def num_players(env: EnvParams) -> int:
    return env.er.shape[0]


def num_dcs(env: EnvParams) -> int:
    return env.er.shape[1]


def capacity_at(env: EnvParams, tau) -> jnp.ndarray:
    """Effective (I, D) execution-rate ceiling ER·avail at hour tau.

    ``avail`` models outages / demand-response curtailment as a fraction of
    each DC's nodes being powered; the paper's setting is avail ≡ 1.
    """
    return env.er * env.avail[:, tau][None, :]


# ---------------------------------------------------------------------------
# paper objective functions
# ---------------------------------------------------------------------------

def dp_max_t(env: EnvParams, tau) -> jnp.ndarray:
    """DP_max[d] at hour tau (eq. 9)."""
    it = (env.it_idle + env.it_dyn) * env.avail[:, tau]
    crac = jnp.minimum(it / power_cop(env), CRAC_PER_DC * CRAC_MAX_W)
    return (it + crac) * env.eff - env.rp[:, tau]


def power_cop(env: EnvParams) -> jnp.ndarray:
    t = env.tsupply
    return 0.0068 * t * t + 0.0008 * t + 0.458


def dp_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """DP_est[i, d] (eq. 10): share of DP_max by rate fraction."""
    frac = ar / jnp.maximum(capacity_at(env, tau), 1e-9)
    return dp_max_t(env, tau)[None, :] * frac


def cet_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """CET[i] (eqs. 11–12): estimated cloud carbon per player, kg/h."""
    de = env.carbon[:, tau][None, :] * dp_est(env, ar, tau) / 1000.0
    return jnp.sum(de, axis=1)


def ce_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """CE (eq. 13): total estimated cloud carbon."""
    return jnp.sum(cet_est(env, ar, tau))


def nc_est(env: EnvParams, ar: jnp.ndarray) -> jnp.ndarray:
    """NC_est[i, d] (eqs. 14–15)."""
    ncmax = env.nprice * env.nn_total[None, :] * env.sizes[:, None]
    return ncmax * ar / jnp.maximum(env.er, 1e-9)


def grid_power(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """Detailed net DC power DP[d] (eq. 4) for a full assignment."""
    rho = jnp.sum(ar / jnp.maximum(capacity_at(env, tau), 1e-9), axis=0)  # (D,)
    a = env.avail[:, tau]
    it = (env.it_idle + env.it_dyn * jnp.clip(rho, 0.0, 1.0)) * a
    crac = jnp.minimum(it / power_cop(env), CRAC_PER_DC * CRAC_MAX_W)
    return (it + crac) * env.eff - env.rp[:, tau]


def peak_increase(env: EnvParams, ar: jnp.ndarray, tau, peak_state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Δ_peak[d] (eq. 6) in $, plus the updated monthly peak state (W)."""
    draw = jnp.maximum(grid_power(env, ar, tau), 0.0)
    new_peak = jnp.maximum(peak_state, draw)
    delta = env.peak_price * (new_peak - peak_state) / 1000.0
    return delta, new_peak


def cct_est(env: EnvParams, ar: jnp.ndarray, tau, peak_state: jnp.ndarray) -> jnp.ndarray:
    """CCT[i] (eqs. 16–17): estimated cloud operating cost per player, $/h."""
    dpe = dp_est(env, ar, tau)  # (I, D) W
    a = jnp.where(dpe > 0, 1.0, env.alpha[None, :])
    energy = env.eprice[:, tau][None, :] * a * dpe / 1000.0
    delta, _ = peak_increase(env, ar, tau, peak_state)
    dc = energy + delta[None, :] + nc_est(env, ar)
    return jnp.sum(dc, axis=1)


def cc_est(env: EnvParams, ar: jnp.ndarray, tau, peak_state: jnp.ndarray) -> jnp.ndarray:
    """CC (eq. 18)."""
    return jnp.sum(cct_est(env, ar, tau, peak_state))


def player_reward(env, ar, tau, peak_state, objective: str) -> jnp.ndarray:
    """(I,) per-player objective value (lower is better)."""
    if objective == "carbon":
        return cet_est(env, ar, tau)
    return cct_est(env, ar, tau, peak_state)


# ---------------------------------------------------------------------------
# constraints (eqs. 1–2)
# ---------------------------------------------------------------------------

def feasible_violation(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """Aggregate constraint violation (0 when feasible)."""
    split = jnp.abs(jnp.sum(ar, axis=1) - env.car[:, tau])  # eq. (1)
    over = jnp.maximum(ar - capacity_at(env, tau), 0.0)     # eq. (2)
    return jnp.sum(split) + jnp.sum(over)


def project_feasible(env: EnvParams, fractions: jnp.ndarray, tau) -> jnp.ndarray:
    """Map simplex fractions (I, D) → feasible AR (both constraints).

    Rates beyond a DC's effective ER (ER·avail, so outage/curtailment
    windows shed correctly) are redistributed to DCs with headroom
    (iterative water-filling, 4 rounds is enough at <=60% utilization).
    If the whole fleet lacks headroom the residual is dropped — eq. (1)
    then reports the shed load as violation, which is physically right.
    """
    car = env.car[:, tau]
    er_t = capacity_at(env, tau)
    ar = fractions * car[:, None]

    def body(ar, _):
        over = jnp.maximum(ar - er_t, 0.0)
        ar = ar - over
        head = jnp.maximum(er_t - ar, 0.0)
        w = head / jnp.maximum(jnp.sum(head, axis=1, keepdims=True), 1e-9)
        ar = ar + jnp.sum(over, axis=1, keepdims=True) * w
        return ar, None

    ar, _ = jax.lax.scan(body, ar, None, length=4)
    return jnp.minimum(ar, er_t)


# ---------------------------------------------------------------------------
# detailed epoch simulation (ground-truth metrics, not the estimate)
# ---------------------------------------------------------------------------

def step_epoch(
    env: EnvParams, peak_state: jnp.ndarray, ar: jnp.ndarray, tau
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Simulate one epoch under assignment ``ar``; returns (new_peak, metrics)."""
    dp = grid_power(env, ar, tau)  # (D,) W, can be negative
    de = env.carbon[:, tau] * dp / 1000.0  # kg/h (negative = displaced grid carbon)
    a = jnp.where(dp > 0, 1.0, env.alpha)
    energy_cost = env.eprice[:, tau] * a * dp / 1000.0
    delta, new_peak = peak_increase(env, ar, tau, peak_state)
    net_cost = jnp.sum(env.nprice * env.sizes[:, None] * ar, axis=0) / 1000.0
    total_cost = energy_cost + delta + net_cost
    viol = feasible_violation(env, ar, tau)
    rho = jnp.sum(ar / jnp.maximum(capacity_at(env, tau), 1e-9), axis=0)
    metrics = {
        "carbon_kg": jnp.sum(de),
        "cost_usd": jnp.sum(total_cost),
        "energy_cost_usd": jnp.sum(energy_cost),
        "peak_cost_usd": jnp.sum(delta),
        "network_cost_usd": jnp.sum(net_cost),
        "grid_power_w": jnp.sum(jnp.maximum(dp, 0.0)),
        "violation": viol,
        "max_rho": jnp.max(rho),
    }
    return new_peak, metrics
