"""The geo-distributed cloud environment: paper eqs. (1)–(18) in JAX.

Everything is a pure function of an ``EnvParams`` NamedTuple of jnp arrays,
so objectives are jittable, vmappable (batched game episodes, and the
scenario engine's ``run_days_batched`` fleet evaluation) and differentiable
(the NASH best-reply baseline exploits the gradients).

Shapes: I task types × D data centers × 24 UTC hours.

Units (every cost metric is $ per one-hour epoch):

====================  =========  =================================================
field / quantity      shape      unit
====================  =========  =================================================
``er``, ``car``       (I, D)/…   tasks/h
``it_idle``/``dyn``   (D,)       W
``rp``                (D, 24)    W
``eprice``            (D, 24)    $/kWh (applied to W/1000 → $/h)
``peak_price``        (D,)       $/kW-month (applied to peak W/1000)
``nprice``            scalar     $/GB (× ``sizes`` GB/task × AR tasks/h → $/h)
``carbon``            (D, 24)    kg CO₂ / kWh (→ kg/h)
``rtt``               (D, D)     ms round-trip between regions (row = source);
                                 canonical — the old (D,) mean-RTT vector form
                                 is gone (routing needs per-path values)
``sla_ms``            (I,)       ms response-time target per task type
``sla_price``         (I,)       $/task charged per expected SLA miss
``sla_weight``        scalar     weight of the SLA term in ``cost_sla`` rewards
``origin``            (S, I, 24) demand-origin split: fraction of task i's
                                 hour-t arrivals sourced from region s (sums
                                 to 1 over s); S = D (sources = DC regions,
                                 the default) or S = 1 (aggregate source)
latency               (I, D)     ms = access RTT + M/M/c-style queued service
routed latency        (S, I, D)  ms = rtt[s, d] + the same queued sojourn
SLA miss cost         (I, D)     $/h = sla_price · AR · p_miss(latency, sla_ms)
routed SLA miss cost  (S, I, D)  $/h priced per (source, task) path
====================  =========  =================================================

Token units (``workload="llm"``, the capability layer in
``dcsim.capability``): task types are model families and every field above
keeps its unit — only the derivation changes. One "task" is one request of
``prompt_mean + output_mean`` tokens, so ``er`` is requests/h derived from
roofline tokens/sec/chip summed over the DC's accelerator mix, service time
``3.6e6 / er`` ms is the request's prefill + decode walltime, ``it_dyn`` is
the accelerator fleet's peak draw with J/token × tokens/s/chip ==
dynamic W/chip by construction, and ``sizes`` is the request's token payload
in GB. The solvers cannot tell the difference — ``EnvParams`` is the whole
interface.

Beyond-paper extensions for the scenario engine (``repro.scenarios``):
``carbon`` carries an hourly axis (D, 24) so grid carbon-intensity events
(spikes, diurnal marginal-carbon shapes) are expressible, and ``avail``
(D, 24) masks per-DC capacity over the day (outages, demand-response
curtailment). The SLA/latency subsystem (``dcsim.latency``) adds ``rtt``,
``sla_ms``, ``sla_price`` and ``sla_weight``; with the defaults
(``rtt = 0``, ``sla_price = 0``) every SLA term is exactly zero. With
``avail == 1``, a constant carbon profile and the default SLA fields the
model reduces exactly to the paper's.

Per-source request routing (beyond-paper): ``origin`` (S, I, 24) records
*where* each task type's demand comes from, and the routed action space is
an (S, I, D) tensor — which region's requests go to which DC. The routed
functions (``project_feasible_routed``, ``latency_ms_routed``,
``sla_cost_routed``) price response time per (source, task) path instead of
against the fleet-mean access RTT; ``step_epoch``/``player_reward`` accept
either an (I, D) or an (S, I, D) assignment. The degenerate S = 1 aggregate
source reproduces the unrouted model bit-for-bit (its single source row is
the uniform-origin mean RTT), and is the parity reference for the engines.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import capability, latency, renewables, topology
from . import workload as _workload
from .topology import CRAC_MAX_W, CRAC_PER_DC, NETWORK_PRICE
from ..units import W_PER_KW


class EnvParams(NamedTuple):
    """Everything the simulator knows about the fleet, one hour-indexed
    pytree. Shapes are pinned in ``repro.lint.pytrees.SCHEMAS``; the field
    units below are the single source of truth for the dimensional
    analysis — ``repro.lint.units`` parses this table and cross-checks it
    against the field declarations, so doc drift is a lint failure.

    Machine-read unit table (repro.lint.units):

        er: task/h
        it_idle: W
        it_dyn: W
        tsupply: degC
        eff: 1
        rp: W
        carbon: kgCO2/kWh
        eprice: USD/kWh
        peak_price: USD/kW
        alpha: 1
        nprice: USD/GB
        sizes: GB/task
        nn_total: node
        car: task/h
        avail: 1
        rtt: ms
        sla_ms: ms
        sla_price: USD/task
        sla_weight: 1
        origin: 1

    (``peak_price`` is $/kW of monthly peak; the monthly billing period is
    deliberately outside the dimension system — the peak delta is a one-off
    $ charge within the hour it occurs.)
    """
    er: jnp.ndarray          # (I, D) max execution rate, tasks/h (eq. 3)
    it_idle: jnp.ndarray     # (D,) W
    it_dyn: jnp.ndarray      # (D,) W at full utilization
    tsupply: jnp.ndarray     # (D,) CRAC supply temperature °C
    eff: jnp.ndarray         # (D,) PSU overhead ≥ 1
    rp: jnp.ndarray          # (D, 24) renewable W
    carbon: jnp.ndarray      # (D, 24) kg CO2 / kWh (hourly grid intensity)
    eprice: jnp.ndarray      # (D, 24) $/kWh TOU
    peak_price: jnp.ndarray  # (D,) $/kW-month
    alpha: jnp.ndarray       # (D,) net metering fraction
    nprice: jnp.ndarray      # scalar $/GB
    sizes: jnp.ndarray       # (I,) GB per task
    nn_total: jnp.ndarray    # (D,) node count
    car: jnp.ndarray         # (I, 24) cloud arrival rates
    avail: jnp.ndarray       # (D, 24) capacity availability in [0, 1]
    rtt: jnp.ndarray         # (D, D) inter-region RTT ms (canonical; row = source)
    sla_ms: jnp.ndarray      # (I,) response-time SLA target, ms
    sla_price: jnp.ndarray   # (I,) $/task per expected SLA miss (0 = unpriced)
    sla_weight: jnp.ndarray  # scalar weight of the SLA term under "cost_sla"
    origin: jnp.ndarray      # (S, I, 24) demand-origin split, sums to 1 over s


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def build_env(
    num_dcs: int = 4,
    *,
    month: int = 6,
    pattern: str = "sinusoidal",
    seed: int = 0,
    utilization: float = 0.45,
    include_tpu: bool = False,
    renewable_scale: float = 0.8,
    workload: "str | capability.WorkloadModel" = "aibench",
) -> EnvParams:
    """Build one day's EnvParams for ``num_dcs`` data centers.

    ``workload`` selects the capability layer (``dcsim.capability``)
    that derives the per-(task, DC) serving numbers — ``er``, IT power,
    payload ``sizes``, default ``sla_ms`` and the task-type count ``I``:

    - ``"aibench"`` (default): the paper's ten AIBench task types on the
      Xeon fleet; bit-for-bit identical to the pre-capability-layer env.
    - ``"llm"``: model-zoo families on the accelerator fleet, derived from
      the roofline (tokens/sec/chip, J/token — see ``capability.py``).
    - any registered name or a ``WorkloadModel`` instance.
    """
    locs = topology.dc_locations(num_dcs)
    loc_rows = [topology.LOCATIONS[i] for i in locs]
    wl = capability.resolve(workload, include_tpu=include_tpu)
    cap = wl.capabilities(num_dcs, seed)
    er, it_idle, it_dyn = cap.er, cap.it_idle, cap.it_dyn
    num_tasks = er.shape[0]
    rng = np.random.default_rng(seed + 17)
    tsupply = rng.uniform(16.0, 24.0, num_dcs)
    eff = rng.uniform(1.10, 1.25, num_dcs)

    tz = np.array([r[2] for r in loc_rows])
    carbon = np.array([r[3] for r in loc_rows])
    base_price = np.array([r[4] for r in loc_rows])
    peak_price = np.array([r[5] for r in loc_rows])
    alpha = np.array([r[6] for r in loc_rows])
    solar_cap = np.array([r[7] for r in loc_rows])
    wind_cap = np.array([r[8] for r in loc_rows])

    # TOU profile: peak window 2–8 PM local at 1.7×, shoulder 1.2×, off 0.8×
    hours = np.arange(24)
    eprice = np.zeros((num_dcs, 24))
    for d in range(num_dcs):
        local = (hours + tz[d]) % 24
        mult = np.where((local >= 14) & (local < 20), 1.7,
                        np.where((local >= 8) & (local < 14), 1.2, 0.8))
        eprice[d] = base_price[d] * mult

    installed = renewable_scale * (it_idle + 0.5 * it_dyn)
    rp = renewables.renewable_profile(tz, solar_cap, wind_cap, 1.0, month, seed)
    rp = rp * installed[:, None]

    # peak rate per type via workload.base_rates (one source of truth for the
    # Dirichlet task mix): w_i (Σw=1) of its own capacity × utilization, so
    # total utilization Σ_i CAR_i/cap_i peaks near ``utilization``.
    base = _workload.base_rates(np.asarray(er).sum(axis=1), utilization)
    car = _workload.arrival_pattern(pattern, base, seed=seed)

    f = jnp.asarray
    return EnvParams(
        er=f(er), it_idle=f(it_idle), it_dyn=f(it_dyn), tsupply=f(tsupply),
        eff=f(eff), rp=f(rp), carbon=f(np.tile(carbon[:, None], (1, 24))),
        eprice=f(eprice), peak_price=f(peak_price), alpha=f(alpha),
        nprice=jnp.float32(NETWORK_PRICE), sizes=f(cap.sizes),
        nn_total=f(cap.nn_total), car=f(car),
        avail=jnp.ones((num_dcs, 24)),
        # SLA/latency defaults: the paper's model (no WAN delay, misses
        # unpriced). sla_ms is a finite slack target so sla_tighten scales it.
        rtt=jnp.zeros((num_dcs, num_dcs)),
        sla_ms=f(cap.sla_ms),
        sla_price=jnp.zeros(num_tasks),
        sla_weight=jnp.float32(1.0),
        # demand origins: uniform across the DC regions (S = D). Routing only
        # matters once rtt is non-zero and origins are shifted; the default
        # reduces the routed model to the paper's exactly.
        origin=jnp.full((num_dcs, num_tasks, 24), 1.0 / num_dcs, dtype=jnp.float32),
    )


def stack_envs(envs) -> EnvParams:
    """Stack same-shape envs leaf-wise into one batched EnvParams.

    The leading axis is a scenario-day (or calendar-day) batch: vmap over it
    for fleet evaluation (``schedulers.run_days_batched``) or scan over it
    for month-scale episodes (``schedulers.run_month``).
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *list(envs))


def tile_env(env: EnvParams, n: int) -> EnvParams:
    """Broadcast one env to a leading axis of ``n`` identical days."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), env)


def pad_env_batch(env_b: EnvParams, n: int) -> EnvParams:
    """Pad a stacked EnvParams' leading axis to ``n`` rows by repeating the
    last scenario-day.

    The device-sharded batched engine needs the env axis divisible by the
    mesh size; padding with a real row keeps every shard's program identical
    (the caller drops the padded rows' metrics).
    """
    m = int(env_b.er.shape[0])
    if n == m:
        return env_b
    if n < m:
        raise ValueError(f"cannot pad a {m}-row batch down to {n}")
    return jax.tree_util.tree_map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[-1:], (n - m,) + x.shape[1:])]), env_b)


def num_players(env: EnvParams) -> int:
    return env.er.shape[0]


def num_dcs(env: EnvParams) -> int:
    return env.er.shape[1]


def capacity_at(env: EnvParams, tau) -> jnp.ndarray:
    """Effective (I, D) execution-rate ceiling ER·avail at hour tau.

    ``avail`` models outages / demand-response curtailment as a fraction of
    each DC's nodes being powered; the paper's setting is avail ≡ 1.
    """
    return env.er * env.avail[:, tau][None, :]


# ---------------------------------------------------------------------------
# per-source request routing: the (S, I, D) decision surface
# ---------------------------------------------------------------------------

def num_sources(env: EnvParams) -> int:
    return env.origin.shape[0]


def origin_at(env: EnvParams, tau) -> jnp.ndarray:
    """(S, I) demand-origin split at hour tau (columns sum to 1 over s)."""
    return env.origin[:, :, tau]


def source_rtt(env: EnvParams) -> jnp.ndarray:
    """(S, D) source-region → DC round trip.

    Sources are either the DC regions themselves (S = D: the RTT matrix
    verbatim) or the degenerate aggregate source (S = 1: the uniform-origin
    row mean — exactly what the unrouted model prices, so S = 1 routing is
    the bit-for-bit parity reference).
    """
    s, d = num_sources(env), num_dcs(env)
    if s == d:
        return env.rtt
    if s == 1:
        return jnp.mean(env.rtt, axis=0, keepdims=True)
    raise ValueError(
        f"origin has {s} source regions; expected {d} (DC regions) or 1")


def aggregate_origin(env: EnvParams) -> EnvParams:
    """Collapse ``origin`` to the degenerate S = 1 aggregate source.

    The routed engines on the result reproduce the unrouted (PR 3) numbers
    bit-for-bit: one source row at the uniform-origin mean RTT.
    """
    i = num_players(env)
    return env._replace(origin=jnp.ones((1, i, 24), env.origin.dtype))


def project_feasible_routed(env: EnvParams, fractions: jnp.ndarray, tau) -> jnp.ndarray:
    """Map routing fractions (S, I, D) — simplex rows over D per (source,
    task) — to a feasible routed assignment AR3 (S, I, D), tasks/h.

    Feasibility is defined on the totals: Σ_s AR3 obeys eqs. (1)–(2) via the
    same water-filling as the unrouted ``project_feasible`` applied to the
    demand-aggregated fractions Σ_s origin[s, i] · fractions[s, i, :]. Each
    feasible (i, d) cell is then split across sources in proportion to the
    requested per-source mass (capacity shedding hits every source of a cell
    equally); mass water-filled into cells no source requested splits by the
    hour's origin mix. With S = 1 the routed projection *is*
    ``project_feasible`` (one source owns all demand, origin ≡ 1): the
    static shortcut keeps forward values and gradients bit-identical to the
    unrouted game — the ratio path below is 1.0 in value but its quotient
    rule would perturb gradients in the last ulp.
    """
    if fractions.shape[0] == 1:
        return project_feasible(env, fractions[0], tau)[None]
    origin = origin_at(env, tau)                                  # (S, I)
    agg = jnp.sum(origin[:, :, None] * fractions, axis=0)         # (I, D)
    ar = project_feasible(env, agg, tau)                          # (I, D)
    demand = env.car[:, tau][None, :] * origin                    # (S, I)
    req3 = demand[:, :, None] * fractions                         # (S, I, D)
    req = jnp.sum(req3, axis=0)                                   # (I, D)
    ratio = jnp.where(req[None] > 1e-9,
                      req3 / jnp.maximum(req[None], 1e-9),
                      origin[:, :, None])
    return ar[None] * ratio


# ---------------------------------------------------------------------------
# paper objective functions
# ---------------------------------------------------------------------------

def crac_cap_t(env: EnvParams, tau) -> jnp.ndarray:
    """(D,) CRAC cooling-power ceiling at hour tau, scaled by ``avail``: a
    curtailed/outaged DC has proportionally less cooling headroom too."""
    return CRAC_PER_DC * CRAC_MAX_W * env.avail[:, tau]


def dp_max_t(env: EnvParams, tau) -> jnp.ndarray:
    """DP_max[d] at hour tau (eq. 9)."""
    it = (env.it_idle + env.it_dyn) * env.avail[:, tau]
    crac = jnp.minimum(it / power_cop(env), crac_cap_t(env, tau))
    return (it + crac) * env.eff - env.rp[:, tau]


def power_cop(env: EnvParams) -> jnp.ndarray:
    t = env.tsupply
    # empirical CRAC COP fit: the coefficients absorb the degC units
    return 0.0068 * t * t + 0.0008 * t + 0.458  # lint: unit-ok(empirical COP quadratic in supply degC)


def load_share(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """(I, D) per-player share of each DC's load: frac_i / Σ_i frac_i.

    Columns sum to 1 wherever the DC carries load and to 0 where it is idle
    (an idle DC's residual idle/export power is unattributable to players —
    the estimator assigns it to no one).
    """
    frac = ar / jnp.maximum(capacity_at(env, tau), 1e-9)
    rho = jnp.sum(frac, axis=0)
    return frac / jnp.maximum(rho, 1e-9)[None, :]


def dp_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """DP_est[i, d] (eq. 10, reconciled): each player's share of the
    *detailed* DC power ``grid_power`` by load share, so
    Σ_i DP_est[i, d] == DP[d] exactly on every loaded DC.

    (The seed scaled DP_max by the raw rate fraction instead, which both
    over-attributed idle power at low utilization and broke
    estimator-vs-simulator agreement — eq. 18 could not match the detailed
    ``step_epoch`` costs it estimates.)
    """
    return grid_power(env, ar, tau)[None, :] * load_share(env, ar, tau)


def cet_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """CET[i] (eqs. 11–12): estimated cloud carbon per player, kg/h."""
    de = env.carbon[:, tau][None, :] * dp_est(env, ar, tau) / W_PER_KW
    return jnp.sum(de, axis=1)


def ce_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """CE (eq. 13): total estimated cloud carbon."""
    return jnp.sum(cet_est(env, ar, tau))


def nc_est(env: EnvParams, ar: jnp.ndarray) -> jnp.ndarray:
    """NC_est[i, d] (eqs. 14–15): NC_max · AR/ER with NC_max = nprice ·
    sizes · ER (the $/h network bill at full execution rate), which reduces
    to nprice · sizes · AR — identical to what ``step_epoch`` charges.

    (The seed's NC_max was scaled by node counts instead of ER, mis-unitted
    by node·h/task and inconsistent with the detailed simulator.)
    """
    return env.nprice * env.sizes[:, None] * ar


def grid_power(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """Detailed net DC power DP[d] (eq. 4) for a full assignment."""
    rho = jnp.sum(ar / jnp.maximum(capacity_at(env, tau), 1e-9), axis=0)  # (D,)
    a = env.avail[:, tau]
    it = (env.it_idle + env.it_dyn * jnp.clip(rho, 0.0, 1.0)) * a
    crac = jnp.minimum(it / power_cop(env), crac_cap_t(env, tau))
    return (it + crac) * env.eff - env.rp[:, tau]


def peak_increase(env: EnvParams, ar: jnp.ndarray, tau, peak_state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Δ_peak[d] (eq. 6) in $, plus the updated monthly peak state (W)."""
    draw = jnp.maximum(grid_power(env, ar, tau), 0.0)
    new_peak = jnp.maximum(peak_state, draw)
    delta = env.peak_price * (new_peak - peak_state) / W_PER_KW
    return delta, new_peak


def cct_est(env: EnvParams, ar: jnp.ndarray, tau, peak_state: jnp.ndarray) -> jnp.ndarray:
    """CCT[i] (eqs. 16–17): estimated cloud operating cost per player, $/h.

    Reconciled with the detailed simulator: energy is priced on the
    load-share attribution of the actual DC power, and the monthly-peak
    delta is split by the same shares. So Σ_i CCT == the ``step_epoch``
    energy + peak + network costs whenever every DC carries load. (The seed
    added the full fleet delta to *every* player — eq. 18 charged the
    monthly peak I times while the simulator charged it once.)
    """
    share = load_share(env, ar, tau)
    dpe = dp_est(env, ar, tau)
    a = jnp.where(dpe > 0, 1.0, env.alpha[None, :])
    energy = env.eprice[:, tau][None, :] * a * dpe / W_PER_KW
    delta, _ = peak_increase(env, ar, tau, peak_state)
    dc = energy + delta[None, :] * share + nc_est(env, ar)  # lint: unit-ok(peak delta is a one-off $ within the 1 h epoch, commensurable with $/h here)
    return jnp.sum(dc, axis=1)


def cc_est(env: EnvParams, ar: jnp.ndarray, tau, peak_state: jnp.ndarray) -> jnp.ndarray:
    """CC (eq. 18)."""
    return jnp.sum(cct_est(env, ar, tau, peak_state))


# ---------------------------------------------------------------------------
# SLA/latency model (dcsim.latency over EnvParams)
# ---------------------------------------------------------------------------

def latency_ms(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """(I, D) expected response time: mean access RTT + the M/M/c-style
    queued service sojourn at the hour's utilization (``dcsim.latency``).

    ``avail`` cancels out of the zero-load service share (nodes and rate
    curtail together) and enters through rho against effective capacity.

    A fully-dark DC (``avail == 0``, e.g. a realized crash hour) has zero
    effective capacity, so its naive rho is 0/eps — an idle-*fast* server
    that would under-price any allocation still pointing at it. It is
    pinned to saturation instead: the queue factor clamps (finite), the
    miss probability goes to ~1, and residual mass on a dead DC pays full
    SLA freight. Feasible allocations place nothing there, so their
    latency/SLA numbers are unchanged (both are allocation-weighted).
    """
    rho = jnp.sum(ar / jnp.maximum(capacity_at(env, tau), 1e-9), axis=0)
    rho = jnp.where(env.avail[:, tau] > 0.0, rho, 1.0)
    return latency.expected_latency_ms(env.er, env.nn_total, rho, env.rtt)


def sla_cost(env: EnvParams, ar: jnp.ndarray, tau,
             lat_ms: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(I, D) expected SLA-miss cost, $/h: sla_price · AR · p_miss.

    Exactly zero wherever ``sla_price`` is zero (the paper default).
    ``lat_ms`` reuses an already-computed ``latency_ms`` (the eager loop
    engine would otherwise evaluate the queueing model twice per epoch).
    """
    lat = latency_ms(env, ar, tau) if lat_ms is None else lat_ms
    p = latency.sla_miss_prob(lat, env.sla_ms[:, None])
    return env.sla_price[:, None] * ar * p


def sla_cost_est(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """(I,) per-player SLA-miss cost — the latency term of ``cost_sla``.

    Identical to the detailed simulator's charge by construction (both
    price the same expected miss probability), so the estimator/simulator
    consistency extends to the SLA term.
    """
    return jnp.sum(sla_cost(env, ar, tau), axis=1)


def latency_ms_routed(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """(S, I, D) per-path response time: rtt[s, d] + queued sojourn.

    ``ar`` is the assignment that sets utilization — either the (I, D)
    totals or a routed (S, I, D) tensor (summed over sources internally;
    queueing at a DC sees total load regardless of where it came from).
    """
    if ar.ndim == 3:
        ar = jnp.sum(ar, axis=0)
    rho = jnp.sum(ar / jnp.maximum(capacity_at(env, tau), 1e-9), axis=0)
    # dark DC == saturated, not idle-fast (see latency_ms)
    rho = jnp.where(env.avail[:, tau] > 0.0, rho, 1.0)
    return latency.expected_latency_ms_routed(env.er, env.nn_total, rho,
                                              source_rtt(env))


def sla_cost_routed(env: EnvParams, ar3: jnp.ndarray, tau,
                    lat_ms: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(S, I, D) expected SLA-miss cost, $/h, priced per (source, task) path:
    sla_price[i] · AR3[s, i, d] · p_miss(rtt[s, d] + sojourn[i, d]).

    The unrouted ``sla_cost`` prices every request against the fleet-mean
    access RTT; here a scheduler that keeps a region's requests nearby pays
    less than one that back-hauls them cross-country — locality is finally
    priced. ``lat_ms`` reuses an already-computed ``latency_ms_routed``.
    """
    lat3 = latency_ms_routed(env, ar3, tau) if lat_ms is None else lat_ms
    p = latency.sla_miss_prob(lat3, env.sla_ms[None, :, None])
    return env.sla_price[None, :, None] * ar3 * p


def sla_cost_est_routed(env: EnvParams, ar3: jnp.ndarray, tau) -> jnp.ndarray:
    """(I,) per-player SLA-miss cost of a routed assignment — the latency
    term of the routed ``cost_sla`` objective. Identical to the detailed
    simulator's charge by construction (same expected-miss pricing)."""
    return jnp.sum(sla_cost_routed(env, ar3, tau), axis=(0, 2))


OBJECTIVES = ("carbon", "cost", "cost_sla")


def player_reward(env, ar, tau, peak_state, objective: str) -> jnp.ndarray:
    """(I,) per-player objective value (lower is better).

    ``carbon``: CET (eq. 12). ``cost``: CCT (eq. 17). ``cost_sla``: CCT plus
    ``sla_weight`` × the expected SLA-miss cost — the beyond-paper objective
    that prices computational performance into the game.

    ``ar`` is the (I, D) allocation, or a routed (S, I, D) tensor — energy/
    peak/network/carbon terms depend only on the totals Σ_s AR3, while the
    SLA term prices each (source, task) path at its own RTT.
    """
    ar3 = ar if ar.ndim == 3 else None
    if ar3 is not None:
        ar = jnp.sum(ar3, axis=0)
    if objective == "carbon":
        return cet_est(env, ar, tau)
    if objective == "cost":
        return cct_est(env, ar, tau, peak_state)
    if objective == "cost_sla":
        sla = (sla_cost_est(env, ar, tau) if ar3 is None
               else sla_cost_est_routed(env, ar3, tau))
        return cct_est(env, ar, tau, peak_state) + env.sla_weight * sla
    raise ValueError(f"unknown objective {objective!r}; known: {OBJECTIVES}")


# ---------------------------------------------------------------------------
# constraints (eqs. 1–2)
# ---------------------------------------------------------------------------

def feasible_violation(env: EnvParams, ar: jnp.ndarray, tau) -> jnp.ndarray:
    """Aggregate constraint violation (0 when feasible)."""
    split = jnp.abs(jnp.sum(ar, axis=1) - env.car[:, tau])  # eq. (1)
    over = jnp.maximum(ar - capacity_at(env, tau), 0.0)     # eq. (2)
    return jnp.sum(split) + jnp.sum(over)


def project_feasible(env: EnvParams, fractions: jnp.ndarray, tau) -> jnp.ndarray:
    """Map simplex fractions (I, D) → feasible AR (both constraints).

    Rates beyond a DC's effective ER (ER·avail, so outage/curtailment
    windows shed correctly) are redistributed to DCs with headroom
    (iterative water-filling, 4 rounds is enough at <=60% utilization).
    If the whole fleet lacks headroom the residual is dropped — eq. (1)
    then reports the shed load as violation, which is physically right.
    """
    car = env.car[:, tau]
    er_t = capacity_at(env, tau)
    ar = fractions * car[:, None]

    def body(ar, _):
        over = jnp.maximum(ar - er_t, 0.0)
        ar = ar - over
        head = jnp.maximum(er_t - ar, 0.0)
        w = head / jnp.maximum(jnp.sum(head, axis=1, keepdims=True), 1e-9)
        ar = ar + jnp.sum(over, axis=1, keepdims=True) * w
        return ar, None

    ar, _ = jax.lax.scan(body, ar, None, length=4)
    return jnp.minimum(ar, er_t)


# ---------------------------------------------------------------------------
# detailed epoch simulation (ground-truth metrics, not the estimate)
# ---------------------------------------------------------------------------

def step_epoch(
    env: EnvParams, peak_state: jnp.ndarray, ar: jnp.ndarray, tau
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Simulate one epoch under assignment ``ar``; returns (new_peak, metrics).

    ``ar`` is the (I, D) allocation or a routed (S, I, D) tensor; physics
    (power, carbon, energy/peak/network bills) depends only on the totals,
    while the SLA charge and the ``latency_ms`` metric are priced per
    (source, task) path when routed. ``latency_ms`` is the request-weighted
    mean response time over all assignments; ``sla_miss_cost_usd`` rolls
    into ``cost_usd`` (exactly zero at the default ``sla_price = 0``).
    """
    ar3 = ar if ar.ndim == 3 else None
    if ar3 is not None:
        ar = jnp.sum(ar3, axis=0)
    dp = grid_power(env, ar, tau)  # (D,) W, can be negative
    de = env.carbon[:, tau] * dp / W_PER_KW  # kg/h (negative = displaced grid carbon)
    a = jnp.where(dp > 0, 1.0, env.alpha)
    energy_cost = env.eprice[:, tau] * a * dp / W_PER_KW
    delta, new_peak = peak_increase(env, ar, tau, peak_state)
    # $/GB × GB/task × tasks/h is already $/h (the seed divided by 1000 and
    # under-counted the detailed network bill 1000× vs the estimator)
    net_cost = jnp.sum(env.nprice * env.sizes[:, None] * ar, axis=0)
    if ar3 is None:
        lat = latency_ms(env, ar, tau)          # (I, D) ms
        sla = jnp.sum(sla_cost(env, ar, tau, lat_ms=lat), axis=0)  # (D,) $/h
        lat_mean = jnp.sum(ar * lat) / jnp.maximum(jnp.sum(ar), 1e-9)
    else:
        lat = latency_ms_routed(env, ar3, tau)  # (S, I, D) ms per path
        sla = jnp.sum(sla_cost_routed(env, ar3, tau, lat_ms=lat), axis=(0, 1))
        lat_mean = jnp.sum(ar3 * lat) / jnp.maximum(jnp.sum(ar3), 1e-9)
    total_cost = energy_cost + delta + net_cost + sla  # lint: unit-ok(peak delta is a one-off $ within the 1 h epoch, commensurable with $/h here)
    viol = feasible_violation(env, ar, tau)
    rho = jnp.sum(ar / jnp.maximum(capacity_at(env, tau), 1e-9), axis=0)
    metrics = {
        "carbon_kg": jnp.sum(de),
        "cost_usd": jnp.sum(total_cost),
        "energy_cost_usd": jnp.sum(energy_cost),
        "peak_cost_usd": jnp.sum(delta),
        "network_cost_usd": jnp.sum(net_cost),
        "sla_miss_cost_usd": jnp.sum(sla),
        "latency_ms": lat_mean,
        "grid_power_w": jnp.sum(jnp.maximum(dp, 0.0)),
        "violation": viol,
        "max_rho": jnp.max(rho),
    }
    return new_peak, metrics
