"""Pluggable workload capability layer: what can each DC serve, and at what
power/latency?

``build_env`` used to hard-wire the AIBench constants (``topology.TASK_TYPES``
execution times through ``colocation.er_table`` + ``power.node_power_arrays``).
This module extracts that derivation behind a ``WorkloadModel`` interface so
the task-type axis ``I`` and the per-(task, DC) capability numbers become a
pluggable implementation choice:

- ``"aibench"`` (the default): the paper's ten AIBench task types on the
  heterogeneous Xeon fleet — an exact, bit-for-bit mirror of the pre-layer
  ``build_env`` ops (pinned by ``tests/test_capability.py``).
- ``"llm"``: task classes are model *families* from the ``configs/`` model
  zoo; each DC's tasks/h, W, and ms are **derived** from the roofline
  constants in ``launch/roofline.py`` applied to that DC's accelerator mix
  (``topology.ACCEL_TYPES`` / ``accel_mix``) — compute/memory/collective
  bottleneck terms → tokens/sec/chip, idle+dynamic node power → J/token,
  with per-family prompt/output token-length statistics and a KV-cache
  occupancy batching factor. No hand-set per-task execution-time constants
  exist on this path; the only constants are hardware specs (FLOP/s, bytes/s,
  GiB, W) and workload statistics (token lengths, target batch).

A ``WorkloadModel`` produces a :class:`CapabilityBundle` — the
``(er, node power, sizes, sla_ms)`` bundle ``env.build_env`` consumes; the
solvers never see any of this (they only see ``EnvParams``), which is why all
six techniques run unchanged on derived envs of any ``I``.

Registering a custom model::

    class MyWorkload:
        name = "mine"
        def capabilities(self, num_dcs, seed):
            return CapabilityBundle(...)

    capability.register_workload("mine", MyWorkload)
    env = E.build_env(4, workload="mine")       # or workload=MyWorkload()
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, NamedTuple, Tuple, Union

import numpy as np

from . import colocation, latency, power, topology
from ..units import BYTES_PER_FP32_TOKEN, BYTES_PER_GB, BYTES_PER_GIB, S_PER_H

__all__ = [
    "CapabilityBundle", "WorkloadModel", "ServingProfile",
    "AIBenchWorkload", "LLMWorkload", "LLM_FAMILIES",
    "register_workload", "get_workload", "workload_names", "resolve",
]


class CapabilityBundle(NamedTuple):
    """Everything ``build_env`` needs to know about a fleet's serving ability.

    Fields (np arrays; D = num DCs, I = task types / model families):

    ==============  =========  ====================================================
    field           shape      units
    ==============  =========  ====================================================
    task_names      (I,) tup   task-type / model-family labels
    er              (I, D)     execution rate, tasks/h at full allocation
    it_idle         (D,)       fleet idle IT power, W
    it_dyn          (D,)       fleet peak dynamic IT power, W
    nn_total        (D,)       node count per DC (M/M/c server count proxy)
    sizes           (I,)       per-task network payload, GB
    sla_ms          (I,)       default SLA latency target, ms
    meta            dict       model-specific extras (llm: tokens/s/chip,
                               J/token, batch, chips per instance, bottleneck)
    ==============  =========  ====================================================

    Machine-read unit table (repro.lint.units):

        task_names: -
        er: task/h
        it_idle: W
        it_dyn: W
        nn_total: node
        sizes: GB/task
        sla_ms: ms
        meta: -
    """

    task_names: Tuple[str, ...]
    er: np.ndarray
    it_idle: np.ndarray
    it_dyn: np.ndarray
    nn_total: np.ndarray
    sizes: np.ndarray
    sla_ms: np.ndarray
    meta: Dict


class WorkloadModel:
    """Interface: a named producer of :class:`CapabilityBundle`.

    Implementations must be deterministic in ``(num_dcs, seed)`` — the same
    arguments must yield the same bundle, because the bundle feeds the
    bit-for-bit-pinned ``EnvParams`` construction.
    """

    name: str = "abstract"

    def capabilities(self, num_dcs: int, seed: int) -> CapabilityBundle:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# aibench: the paper's constants, extracted verbatim
# ---------------------------------------------------------------------------

class AIBenchWorkload(WorkloadModel):
    """The paper's AIBench task types on the heterogeneous Xeon fleet.

    An exact transplant of the capability ops ``build_env`` ran before this
    layer existed — same calls, same order, same seeds — so the default
    ``build_env(workload="aibench")`` is bit-for-bit the pre-layer env
    (pinned by ``tests/test_capability.py::test_aibench_pin``).

    ``include_tpu`` is aibench-specific: it carves a TPU aisle out of the
    Xeon mix (the pre-layer ``build_env(include_tpu=True)`` path).
    """

    name = "aibench"

    def __init__(self, include_tpu: bool = False):
        self.include_tpu = include_tpu

    def capabilities(self, num_dcs: int, seed: int) -> CapabilityBundle:
        nn = topology.node_mix(seed, num_dcs, include_tpu=self.include_tpu)
        er = colocation.er_table(nn)
        idle, dyn = power.node_power_arrays(nn.shape[1])
        nn_total = nn.sum(axis=1).astype(float)
        sizes = np.array([t[2] for t in topology.TASK_TYPES])
        sla_ms = latency.default_sla_ms(er, nn_total)
        names = tuple(t[0] for t in topology.TASK_TYPES)
        return CapabilityBundle(
            task_names=names, er=np.asarray(er), it_idle=nn @ idle,
            it_dyn=nn @ dyn, nn_total=nn_total, sizes=sizes, sla_ms=sla_ms,
            meta={"nn": nn},
        )


# ---------------------------------------------------------------------------
# llm: model-zoo families on the accelerator fleet, derived from the roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingProfile:
    """Workload *statistics* for one served model family (request shapes —
    not execution times; those are derived).

    Machine-read unit table (repro.lint.units):

        arch: -
        prompt_mean: token/task
        output_mean: token/task
        batch_target: 1
        extra_payload_gb: GB/task
    """

    arch: str              # configs/ model-zoo name
    prompt_mean: int       # mean prompt length, tokens
    output_mean: int       # mean output length, tokens
    batch_target: int      # serving batch ceiling (KV capacity may bind first)
    extra_payload_gb: float = 0.0  # non-text payload (audio/video), GB


# family -> profile. Six families (deliberately != the aibench I=10: the
# task-type axis is data-driven, exercised by the I!=5 engine smoke).
LLM_FAMILIES: Tuple[Tuple[str, ServingProfile], ...] = (
    ("chat-1b", ServingProfile("llama3.2-1b", 512, 256, 64)),
    ("chat-7b", ServingProfile("qwen2-7b", 1024, 512, 32)),
    ("moe-light", ServingProfile("qwen2-moe-a2.7b", 1024, 512, 32)),
    ("dense-large", ServingProfile("mistral-large-123b", 2048, 1024, 16)),
    ("moe-480b", ServingProfile("arctic-480b", 2048, 1024, 16)),
    ("audio-asr", ServingProfile("whisper-base", 1500, 180, 48,
                                 extra_payload_gb=0.002)),
)

_DTYPE_BYTES = 2  # bf16 weights and KV cache


def _family_on_accel(profile: ServingProfile, acc: "topology.AccelType"):
    """Derive one (family, accelerator) cell from the roofline.

    Returns ``(tasks_per_h_per_node, tokens_per_s_chip, j_per_token,
    n_chips, bottleneck)``. Pure arithmetic over the ModelConfig and the
    accelerator's hardware spec — the same compute/memory/collective
    bottleneck decomposition as ``roofline.analyze``, applied analytically
    (decode is one token across batch B; prefill is one compute-bound pass
    over the prompt).
    """
    from ..configs import get_config

    cfg = get_config(profile.arch)
    total_b = cfg.param_count() * _DTYPE_BYTES
    active = cfg.param_count(active_only=True)
    hbm_b = acc.hbm_gb * BYTES_PER_GIB

    # chips per model instance: weights must fit in aggregate HBM
    n_chips = max(1, math.ceil(total_b / hbm_b))

    # mean live context per sequence (prompt + half the output, windowed)
    ctx = profile.prompt_mean + profile.output_mean / 2.0
    if cfg.attn_window:
        ctx = min(ctx, float(cfg.attn_window))

    # KV bytes/token: K and V per attention layer (subquadratic blocks carry
    # fixed-size state instead — no per-token growth)
    n_attn = sum(1 for k in cfg.pattern() if k == "attn")
    kv_per_tok = 2 * cfg.kv_dim() * _DTYPE_BYTES * n_attn
    kv_per_seq = kv_per_tok * ctx

    # batch: KV-cache occupancy of the HBM left after weights, capped by the
    # serving target
    free_b = n_chips * hbm_b - total_b
    b = int(np.clip(free_b // max(kv_per_seq, 1.0), 1, profile.batch_target))

    # decode step (one token for each of B sequences), roofline terms:
    flops = 2.0 * active * b                       # matmul FLOPs
    byts = total_b + b * kv_per_seq                # weights + KV streamed
    coll = (b * 4.0 * cfg.d_model * _DTYPE_BYTES * cfg.num_layers
            * (n_chips - 1) / max(n_chips, 1))     # activation all-reduce
    terms = {
        "compute": flops / (n_chips * acc.peak_flops),
        "memory": byts / (n_chips * acc.hbm_bw),
        "collective": coll / acc.ici_bw,
    }
    bottleneck = max(terms, key=terms.get)
    t_step = terms[bottleneck]

    chips_per_node = acc.chips
    tokens_per_s_chip = b / (t_step * n_chips)

    # prefill: one compute-bound pass over the prompt (memory floor: stream
    # the weights once)
    prefill_s = max(2.0 * active * profile.prompt_mean / (n_chips * acc.peak_flops),
                    total_b / (n_chips * acc.hbm_bw))
    req_s = prefill_s + profile.output_mean * t_step / b   # per request
    tasks_per_h_chip = S_PER_H / (req_s * n_chips)
    tasks_per_h_node = tasks_per_h_chip * chips_per_node

    # energy attribution: a chip's dynamic draw divided by its token rate —
    # tokens/s/chip x J/token == dynamic W/chip by construction (the
    # unit-consistency test)
    j_per_token = (acc.dyn_w / chips_per_node) / tokens_per_s_chip
    return tasks_per_h_node, tokens_per_s_chip, j_per_token, n_chips, bottleneck


class LLMWorkload(WorkloadModel):
    """Token-grounded LLM serving: families = model-zoo archs, capability
    derived from the roofline on each DC's accelerator mix.

    ``er[f, d] = sum_a tasks_per_h_per_node[f, a] * accel_mix[d, a]`` — the
    aggregate request rate if the whole fleet served family ``f``; the
    existing M/M/c latency model consumes it unchanged (service time in
    token units: ``3.6e6 / er`` ms/request = prefill + output tokens /
    token rate).
    """

    name = "llm"

    def __init__(self, families: Tuple[Tuple[str, ServingProfile], ...] = LLM_FAMILIES,
                 accel_types: Tuple["topology.AccelType", ...] | None = None):
        self.families = tuple(families)
        self.accel_types = tuple(accel_types if accel_types is not None
                                 else topology.ACCEL_TYPES)

    def capabilities(self, num_dcs: int, seed: int) -> CapabilityBundle:
        accs = self.accel_types
        nn = topology.accel_mix(seed, num_dcs, num_accel_types=len(accs))
        i, a = len(self.families), len(accs)

        tasks_h_node = np.zeros((i, a))
        tok_s_chip = np.zeros((i, a))
        j_tok = np.zeros((i, a))
        chips = np.zeros((i, a), np.int64)
        bneck = np.empty((i, a), object)
        for fi, (_, prof) in enumerate(self.families):
            for ai, acc in enumerate(accs):
                (tasks_h_node[fi, ai], tok_s_chip[fi, ai], j_tok[fi, ai],
                 chips[fi, ai], bneck[fi, ai]) = _family_on_accel(prof, acc)

        er = tasks_h_node @ nn.T.astype(float)           # (I, D) tasks/h
        idle = np.array([acc.idle_w for acc in accs])
        dyn = np.array([acc.dyn_w for acc in accs])
        nn_total = nn.sum(axis=1).astype(float)
        sizes = np.array([
            (p.prompt_mean + p.output_mean) * BYTES_PER_FP32_TOKEN / BYTES_PER_GB
            + p.extra_payload_gb
            for _, p in self.families])                  # ~4 B/token text
        sla_ms = latency.default_sla_ms(er, nn_total)
        return CapabilityBundle(
            task_names=tuple(n for n, _ in self.families),
            er=er, it_idle=nn @ idle, it_dyn=nn @ dyn, nn_total=nn_total,
            sizes=sizes, sla_ms=sla_ms,
            meta={"nn": nn, "tokens_per_s_chip": tok_s_chip,
                  "j_per_token": j_tok, "n_chips": chips,
                  "bottleneck": bneck, "tasks_per_h_node": tasks_h_node,
                  "accel_names": tuple(acc.name for acc in accs)},
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], WorkloadModel]] = {}


def register_workload(name: str, factory: Callable[[], WorkloadModel]) -> None:
    """Register a zero-arg factory (usually the class) under ``name``."""
    _REGISTRY[name] = factory


def workload_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_workload(name: str) -> WorkloadModel:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown workload {name!r}; registered: {workload_names()}")
    return _REGISTRY[name]()


def resolve(workload: Union[str, WorkloadModel], *,
            include_tpu: bool = False) -> WorkloadModel:
    """Name or instance -> WorkloadModel.

    ``include_tpu`` only applies to ``"aibench"`` (the pre-layer carve-out
    flag); passing it with any other name raises so a silently-ignored flag
    can't masquerade as a TPU-aware llm fleet.
    """
    if isinstance(workload, WorkloadModel):
        if include_tpu:
            raise ValueError("include_tpu only applies to workload='aibench'")
        return workload
    if workload == "aibench":
        return AIBenchWorkload(include_tpu=include_tpu)
    if include_tpu:
        raise ValueError("include_tpu only applies to workload='aibench'")
    return get_workload(workload)


register_workload("aibench", AIBenchWorkload)
register_workload("llm", LLMWorkload)
