"""Co-location interference model (paper §3.3.2, model form of [37]).

Execution time of task i on a core of node type j under co-location is a
linear-regression blow-up over the solo base time, driven by: number of
co-located tasks on the package, the task's own memory intensity, the
average memory intensity of residents, and clock frequency. [37] reports
~7% MAPE for this family of models on real Xeon measurements; coefficients
here are synthetic-but-shaped per memory-intensity class.

The CWM-level quantity is the *maximum* execution rate ER[i, d] (eq. 3):
all cores of every node running task i, i.e. co-location with (cores-1)
same-type residents.
"""
from __future__ import annotations

import numpy as np

from ..units import S_PER_H
from .topology import NODE_TYPES, NUM_XEON_TYPES, TASK_TYPES

# interference slope per memory-intensity class (low, med, high):
# fractional exec-time increase per co-resident task per unit avg intensity
CLASS_SLOPE = np.array([0.010, 0.030, 0.065])
# memory intensity value per class (LLC misses / instruction, scaled)
CLASS_INTENSITY = np.array([0.15, 0.45, 0.85])


def base_time_table(num_node_types: int) -> np.ndarray:
    """BET[i, j]: solo execution time (s) of task i on one core of type j."""
    i = len(TASK_TYPES)
    out = np.zeros((i, num_node_types))
    for ti, (_, _, _, times) in enumerate(TASK_TYPES):
        for j in range(min(num_node_types, NUM_XEON_TYPES)):
            out[ti, j] = times[j]
        if num_node_types > NUM_XEON_TYPES:
            # TPU host node: inference offloaded to accelerator, ~20x faster
            out[ti, NUM_XEON_TYPES] = min(times) / 20.0
    return out


def coer_core(num_node_types: int) -> np.ndarray:
    """CoER[i, j]: co-located execution rate (tasks/s) per core (eq. [37]).

    exec_time = BET * (1 + slope_class(i) * (cores_j - 1) * mi_avg)
    with mi_avg = own class intensity (uniform same-type co-location) and a
    mild clock-frequency correction.
    """
    bet = base_time_table(num_node_types)
    i_n = bet.shape[0]
    out = np.zeros_like(bet)
    ghz_ref = 2.8
    for ti in range(i_n):
        cls = TASK_TYPES[ti][1]
        for j in range(num_node_types):
            node = NODE_TYPES[j]
            freq_corr = 1.0 if node.ghz == 0 else (ghz_ref / node.ghz) ** 0.3
            blowup = 1.0 + CLASS_SLOPE[cls] * (node.cores - 1) * CLASS_INTENSITY[cls]
            t = bet[ti, j] * blowup * freq_corr
            out[ti, j] = 1.0 / t
    return out


def er_table(nn: np.ndarray) -> np.ndarray:
    """ER[i, d] tasks/hour (eq. 3): sum of core rates over all nodes of d.

    nn: NN[d, j] node counts.
    """
    num_types = nn.shape[1]
    coer = coer_core(num_types)  # (I, J) tasks/s per core
    cores = np.array([NODE_TYPES[j].cores for j in range(num_types)], float)
    per_node = coer * cores[None, :]  # (I, J) tasks/s per node
    er = per_node @ nn.T.astype(float)  # (I, D) tasks/s
    return er * S_PER_H
