"""Data-center power models (paper §3.3.3, detailed models from [16]).

Node power: idle + dynamic × utilization (per node type, with the dynamic
part averaged over task types as the paper's P_j^D).  Cooling: CRAC power
from compute heat via the classic HP COP(T_supply) quadratic used by [16].
Net DC power (eq. 4): (CRAC + nodes) · Eff − renewables, may be negative.
"""
from __future__ import annotations

import numpy as np

from .topology import CRAC_MAX_W, NODE_TYPES


def cop(t_supply_c: np.ndarray) -> np.ndarray:
    """HP CRAC coefficient-of-performance model."""
    t = np.asarray(t_supply_c, float)
    # the empirical fit's coefficients absorb the degC units
    return 0.0068 * t * t + 0.0008 * t + 0.458  # lint: unit-ok(empirical COP quadratic in supply degC)


def node_power_arrays(num_node_types: int):
    """(idle_w[j], peak_dyn_w[j]) vectors."""
    idle = np.array([NODE_TYPES[j].idle_w for j in range(num_node_types)])
    dyn = np.array([NODE_TYPES[j].peak_dyn_w for j in range(num_node_types)])
    return idle, dyn


def compute_power(nn: np.ndarray, rho: np.ndarray) -> np.ndarray:
    """IT (node) power per DC, W.

    nn: NN[d, j]; rho: (D,) total utilization of each DC in [0, 1]
    (assumes the DWM spreads load so all node types see equal utilization —
    the paper's DWM detail collapses to this at CWM granularity).
    """
    idle, dyn = node_power_arrays(nn.shape[1])
    idle_total = nn @ idle   # (D,)
    dyn_total = nn @ dyn     # (D,)
    return idle_total + dyn_total * np.clip(rho, 0.0, 1.0)


def crac_power(it_power_w: np.ndarray, t_supply_c: np.ndarray) -> np.ndarray:
    """Cooling power needed to extract IT heat at the given supply temp."""
    return it_power_w / cop(t_supply_c)


def dp_max(nn: np.ndarray, eff: np.ndarray, t_supply_c: np.ndarray, ncr: int, rp_w: np.ndarray) -> np.ndarray:
    """DP_max[d] (eq. 9): all nodes at peak dynamic power + rated CRAC."""
    idle, dyn = node_power_arrays(nn.shape[1])
    it = nn @ (idle + dyn)
    crac = np.minimum(crac_power(it, t_supply_c), ncr * CRAC_MAX_W)
    return (it + crac) * eff - rp_w
