"""Sharding rules: param-path → PartitionSpec, activation constraints.

The production mesh axes are ("data", "model") per pod, plus a leading
"pod" axis in the multi-pod mesh. Assignment of tensor dims:

  * batch                → ("pod", "data")        (DP across pods and hosts)
  * attention/MLP width  → "model"                (TP / EP)
  * parameter storage    → optionally also "data" (FSDP / ZeRO-3), flag-gated

Every rule checks divisibility against the actual mesh axis size — GSPMD
rejects uneven shardings at jit boundaries — and falls back to replication
for that dimension (e.g. whisper's 51865 vocab).

Activation constraints are applied through :func:`constrain`, which is a
no-op unless a mesh has been installed with :func:`use_mesh` — so model code
is runnable un-meshed on CPU in the unit tests.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_active_mesh", default=None
)

BATCH_AXES = ("pod", "data")


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install a mesh for activation sharding constraints."""
    token = _ACTIVE_MESH.set(mesh)
    try:
        yield
    finally:
        _ACTIVE_MESH.reset(token)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH.get()


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def mesh_batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 0) -> P:
    """Shard a leading batch dim over as much of the DP axes as divides."""
    axes = mesh_batch_axes(mesh)
    while axes and batch % axis_size(mesh, axes) != 0:
        axes = axes[1:]  # drop "pod" first
    first = axes if axes else None
    return P(first, *([None] * extra_dims))


def _maybe(mesh: Mesh, axes, dim: int):
    """Use ``axes`` for a dim of size ``dim`` only if it divides evenly."""
    if axes is None:
        return None
    if dim % axis_size(mesh, axes) != 0:
        return None
    if isinstance(axes, (tuple, list)) and len(axes) == 1:
        return axes[0]  # older jax PartitionSpec doesn't equate ('x',) == 'x'
    return axes


def constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """with_sharding_constraint against the active mesh (no-op un-meshed).

    ``axes`` entries are mesh axis names / tuples / None, one per dim;
    dims that do not divide evenly fall back to None.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    fixed = []
    for dim, a in zip(x.shape, axes):
        if a is not None and isinstance(a, tuple):
            a = tuple(x_ for x_ in a if x_ in mesh.axis_names) or None
        if a is not None and isinstance(a, str) and a not in mesh.axis_names:
            a = None
        fixed.append(_maybe(mesh, a, dim))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Shard dim0 as batch, replicate the rest."""
    return constrain(x, BATCH_AXES, *([None] * (x.ndim - 1)))


# ---------------------------------------------------------------------------
# Parameter partition rules
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...], fsdp: bool) -> P:
    """Partition rule for one parameter leaf.

    Scanned layer stacks live under ``layers/`` (or ``groups/``, ``enc/``,
    ``dec/``) with a leading depth dim which is never sharded.
    """
    # only scan-stacked containers carry a leading depth dim; "blocks/<i>/"
    # holds ordinary per-layer params
    stacked = bool(re.search(r"(layers|groups)/", path))
    core = shape[1:] if stacked and len(shape) >= 2 else shape
    lead: Tuple = (None,) if stacked and len(shape) >= 2 else ()
    dp = "data" if fsdp else None

    def out(*axes) -> P:
        return P(*lead, *axes)

    name = path.rsplit("/", 2)[-2:]
    leaf = "/".join(name)

    if len(core) == 0:
        return out()
    if "embed/w" in path or "pos_embed" in path:
        # (vocab, d): shard the model dim; vocab replicated (gather-friendly).
        return out(None, _maybe(mesh, "model", core[-1]))
    if len(core) == 3 and "experts" in path:
        e, a, b_ = core
        if _maybe(mesh, "model", e):
            # expert-parallel: experts over "model", optional fsdp inside.
            if leaf.endswith("w_out/w"):
                return out("model", None, _maybe(mesh, dp, b_))
            return out("model", _maybe(mesh, dp, a), None)
        # experts not divisible (qwen2-moe's 60): shard the ffn width instead.
        if leaf.endswith("w_out/w"):
            return out(None, _maybe(mesh, "model", a), _maybe(mesh, dp, b_))
        return out(None, _maybe(mesh, dp, a), _maybe(mesh, "model", b_))
    if len(core) == 2:
        d_in, d_out = core
        if any(k in path for k in ("wo/", "w_out/", "down/")):
            return out(_maybe(mesh, "model", d_in), _maybe(mesh, dp, d_out))
        # default: output-feature sharding (wq/wk/wv/w_in/w_gate/router/head)
        return out(_maybe(mesh, dp, d_in), _maybe(mesh, "model", d_out))
    if len(core) == 1:
        # biases of model-sharded projections follow their outputs; norms and
        # small recurrence params replicate.
        if any(k in path for k in ("wq/", "wk/", "wv/", "w_in/", "w_gate/")):
            return out(_maybe(mesh, "model", core[0]))
        return out(None)
    return out(*([None] * len(core)))


def _cache_spec(mesh: Mesh, path: str, shape: Tuple[int, ...], batch: int) -> P:
    """Partition rule for a decode-cache / recurrent-state leaf.

    KV caches shard their *sequence* dim over "model" (the GSPMD analogue of
    split-KV flash-decode: each model shard holds a contiguous KV span and
    the softmax reduction psums across shards) and batch over the DP axes.
    kv_heads are typically < |model| (GQA/MQA) so the head dim is never the
    sharded one.
    """
    dp = mesh_batch_axes(mesh)
    while dp and batch % axis_size(mesh, dp) != 0:
        dp = dp[1:]
    dpa = dp if dp else None
    leaf = path.rsplit("/", 1)[-1]
    nd = len(shape)
    if nd == 0:
        return P()
    stacked = nd >= 2 and shape[0] != batch and shape[1] == batch
    lead: Tuple = (None,) if stacked else ()
    core = shape[1:] if stacked else shape

    def out(*axes):
        axes = [_maybe(mesh, a, d) for a, d in zip(axes, core)]
        return P(*lead, *axes)

    if leaf in ("k", "v", "cross_k", "cross_v") and len(core) == 4:
        return out(dpa, "model", None, None)  # (B, S, KVH, hd): shard seq
    if leaf == "pos":
        return P(*lead) if len(core) == 0 else out(dpa)
    if leaf == "conv_buf" and len(core) == 3:
        return out(dpa, None, "model")
    if leaf == "h" and len(core) == 2:
        return out(dpa, "model")
    if leaf in ("c", "n", "m", "C") or len(core) >= 1:
        return out(dpa, *([None] * (len(core) - 1)))
    return P()


def cache_specs_tree(cache: Any, mesh: Mesh, batch: int):
    def rule(path, leaf):
        return _cache_spec(mesh, _path_str(path), tuple(leaf.shape), batch)

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs_tree(batch_tree: Any, mesh: Mesh, batch: int):
    """Model-input specs: shard dim0 (batch) over the DP axes."""
    def rule(path, leaf):
        dp = mesh_batch_axes(mesh)
        while dp and batch % axis_size(mesh, dp) != 0:
            dp = dp[1:]
        first = dp if dp else None
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def param_specs(params: Any, mesh: Mesh, fsdp: bool = False):
    """Pytree of PartitionSpec mirroring ``params`` (works on shapes too)."""

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        return _param_spec(mesh, _path_str(path), shape, fsdp)

    return jax.tree_util.tree_map_with_path(rule, params)


def param_shardings(params: Any, mesh: Mesh, fsdp: bool = False):
    specs = param_specs(params, mesh, fsdp)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


def apply_shardings(params: Any, mesh: Mesh, fsdp: bool = False):
    """Device-put concrete params onto the mesh (used by real runs)."""
    sh = param_shardings(params, mesh, fsdp)
    return jax.tree_util.tree_map(jax.device_put, params, sh)
