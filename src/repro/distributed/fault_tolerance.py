"""Fault tolerance & straggler mitigation for long-running training.

What runs in this container is the control logic, exercised by the tests
with simulated failures; on a real multi-pod deployment the same hooks are
driven by the platform's health signals:

  * ``HeartbeatMonitor`` — detects missing/slow participants from step-time
    telemetry (median-based straggler score, as in production TPU runs where
    a slow HBM or a flaky ICI link shows up as a per-host step-time outlier).
  * ``FailurePolicy`` — decides restart-from-checkpoint vs. elastic
    continue-with-fewer-pods (checkpoints are mesh-shape-agnostic, see
    ``repro.checkpoint``).
  * ``run_with_retries`` — supervisor loop: run the step function, on
    (simulated or real) failure restore the latest checkpoint and resume;
    data pipeline skip-ahead guarantees bitwise-identical batches.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerReport:
    worker: int
    ratio: float  # step time / median step time


class HeartbeatMonitor:
    """Tracks per-worker step durations; flags stragglers and deaths."""

    def __init__(self, num_workers: int, window: int = 16,
                 straggler_ratio: float = 1.5, dead_after_s: float = 60.0):
        self.num_workers = num_workers
        self.window = window
        self.straggler_ratio = straggler_ratio
        self.dead_after_s = dead_after_s
        self._times: List[deque] = [deque(maxlen=window) for _ in range(num_workers)]
        self._last_seen = [time.time()] * num_workers

    def record(self, worker: int, step_time_s: float, now: Optional[float] = None):
        self._times[worker].append(step_time_s)
        self._last_seen[worker] = now if now is not None else time.time()

    def _medians(self) -> List[float]:
        meds = []
        for dq in self._times:
            if dq:
                s = sorted(dq)
                meds.append(s[len(s) // 2])
            else:
                meds.append(float("nan"))
        return meds

    def stragglers(self) -> List[StragglerReport]:
        meds = [m for m in self._medians() if m == m]
        if not meds:
            return []
        global_med = sorted(meds)[len(meds) // 2]
        out = []
        for w, dq in enumerate(self._times):
            if not dq:
                continue
            s = sorted(dq)
            med = s[len(s) // 2]
            if global_med > 0 and med / global_med >= self.straggler_ratio:
                out.append(StragglerReport(w, med / global_med))
        return out

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [w for w, t in enumerate(self._last_seen) if now - t > self.dead_after_s]


@dataclasses.dataclass(frozen=True)
class FailurePolicy:
    max_restarts: int = 10
    elastic: bool = True  # allow continuing on a smaller mesh

    def decide(self, dead_workers: List[int], spare_capacity: int) -> str:
        if not dead_workers:
            return "continue"
        if spare_capacity >= len(dead_workers):
            return "replace"  # hot spares take over, restore from checkpoint
        if self.elastic:
            return "shrink"   # re-shard onto the survivors
        return "restart"


class SimulatedFailure(RuntimeError):
    pass


def run_with_retries(
    step_fn: Callable[[int], Dict],
    *,
    total_steps: int,
    save_every: int,
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    policy: FailurePolicy = FailurePolicy(),
    on_event: Optional[Callable[[str, int], None]] = None,
    retry_on: Tuple[type, ...] = (SimulatedFailure,),
    backoff_s: float = 0.0,
    sleep_fn: Callable[[float], None] = time.sleep,
) -> Dict[str, int]:
    """Supervisor: drive ``step_fn(step)`` to ``total_steps`` with
    checkpoint/restart on failure. Returns counters for the tests.

    ``retry_on`` is the tuple of exception types worth retrying (anything
    else — including ``BaseException`` kills like a real SIGKILL —
    propagates); each restart sleeps ``backoff_s * 2**(restarts-1)`` via the
    injectable ``sleep_fn`` before restoring, so a flapping dependency gets
    exponentially more room instead of a hot retry loop."""
    restarts = 0
    step = restore_fn()
    events = {"restarts": 0, "saves": 0}
    while step < total_steps:
        try:
            step_fn(step)
            step += 1
            if step % save_every == 0:
                save_fn(step)
                events["saves"] += 1
        except retry_on:
            restarts += 1
            events["restarts"] = restarts
            if restarts > policy.max_restarts:
                raise
            if on_event:
                on_event("restart", step)
            if backoff_s > 0.0:
                sleep_fn(backoff_s * (2 ** (restarts - 1)))
            step = restore_fn()
    return events
