"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a stub per the assignment: the model consumes
precomputed frame embeddings (B, Se, D) from ``input_specs()``. Encoder =
non-causal self-attention blocks with sinusoidal positions; decoder = causal
self-attention + cross-attention blocks with learned positions. LayerNorm +
GELU (Whisper convention).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from . import attention as attn
from .layers import (Params, apply_norm, embed, embed_init, norm_init, sinusoidal_positions, unembed)
from .mlp import mlp_apply, mlp_init
from .transformer import _attn_cache_init


def _pdt(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


def enc_block_init(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    dt = _pdt(cfg)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dt),
        "attn": attn.attn_init(ks[0], cfg),
        "norm2": norm_init(cfg.d_model, cfg.norm, dt),
        "mlp": mlp_init(ks[1], cfg),
    }


def dec_block_init(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    dt = _pdt(cfg)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dt),
        "self_attn": attn.attn_init(ks[0], cfg),
        "norm_x": norm_init(cfg.d_model, cfg.norm, dt),
        "cross_attn": attn.attn_init(ks[1], cfg),
        "norm2": norm_init(cfg.d_model, cfg.norm, dt),
        "mlp": mlp_init(ks[2], cfg),
    }


def encdec_init(key, cfg) -> Params:
    ks = jax.random.split(key, 4 + cfg.num_encoder_layers + cfg.num_layers)
    dt = _pdt(cfg)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "pos_embed": {"w": (jax.random.normal(ks[1], (4096, cfg.d_model), jnp.float32) * 0.01).astype(dt)},
        "enc": {str(i): enc_block_init(ks[4 + i], cfg) for i in range(cfg.num_encoder_layers)},
        "enc_norm": norm_init(cfg.d_model, cfg.norm, dt),
        "dec": {
            str(i): dec_block_init(ks[4 + cfg.num_encoder_layers + i], cfg)
            for i in range(cfg.num_layers)
        },
        "dec_norm": norm_init(cfg.d_model, cfg.norm, dt),
    }
    return p


def encode(p: Params, cfg, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, Se, D) stubbed conv-frontend output."""
    se = frames.shape[1]
    x = frames + sinusoidal_positions(se, cfg.d_model).astype(frames.dtype)
    for i in range(cfg.num_encoder_layers):
        bp = p["enc"][str(i)]
        h = apply_norm(bp["norm1"], x, cfg.norm)
        x = x + attn.attn_apply(bp["attn"], cfg, h, jnp.zeros(h.shape[:2], jnp.int32), causal=False)
        x = x + mlp_apply(bp["mlp"], cfg, apply_norm(bp["norm2"], x, cfg.norm))
    return apply_norm(p["enc_norm"], x, cfg.norm)


def _dec_positions(cfg, tokens):
    b, s = tokens.shape
    return jnp.broadcast_to(jnp.arange(s)[None], (b, s))


def decode_train(p: Params, cfg, tokens: jnp.ndarray, enc_out: jnp.ndarray) -> jnp.ndarray:
    b, s = tokens.shape
    pos = _dec_positions(cfg, tokens)
    x = embed(p["embed"], tokens)
    # learned positions (table sized >= max training seq; take mod for safety)
    x = x + jnp.take(p["pos_embed"]["w"], jnp.mod(pos, p["pos_embed"]["w"].shape[0]), axis=0)
    for i in range(cfg.num_layers):
        bp = p["dec"][str(i)]
        h = apply_norm(bp["norm1"], x, cfg.norm)
        x = x + attn.attn_apply(bp["self_attn"], cfg, h, pos, causal=True)
        hx = apply_norm(bp["norm_x"], x, cfg.norm)
        kv = attn.cross_kv(bp["cross_attn"], cfg, enc_out)
        x = x + attn.cross_attn_apply(bp["cross_attn"], cfg, hx, kv)
        x = x + mlp_apply(bp["mlp"], cfg, apply_norm(bp["norm2"], x, cfg.norm))
    x = apply_norm(p["dec_norm"], x, cfg.norm)
    return unembed(p["embed"], x)


def init_dec_cache(p: Params, cfg, enc_out: jnp.ndarray, batch: int, cache_len: int, dtype):
    """Self-attn KV caches + precomputed cross-attn KV per layer."""
    caches: List[Dict[str, Any]] = []
    for i in range(cfg.num_layers):
        bp = p["dec"][str(i)]
        k, v = attn.cross_kv(bp["cross_attn"], cfg, enc_out)
        caches.append({
            "self": _attn_cache_init(cfg, batch, cache_len, dtype),
            "cross_k": k.astype(dtype),
            "cross_v": v.astype(dtype),
        })
    return caches


def decode_step(p: Params, cfg, token: jnp.ndarray, positions: jnp.ndarray, caches):
    """token: (B, 1); positions: (B, 1) absolute decoder positions."""
    x = embed(p["embed"], token)
    x = x + jnp.take(p["pos_embed"]["w"], jnp.mod(positions, p["pos_embed"]["w"].shape[0]), axis=0)
    new_caches = []
    for i in range(cfg.num_layers):
        bp = p["dec"][str(i)]
        h = apply_norm(bp["norm1"], x, cfg.norm)
        y, self_cache = attn.attn_decode(bp["self_attn"], cfg, h, positions, caches[i]["self"])
        x = x + y
        hx = apply_norm(bp["norm_x"], x, cfg.norm)
        x = x + attn.cross_attn_apply(
            bp["cross_attn"], cfg, hx, (caches[i]["cross_k"], caches[i]["cross_v"])
        )
        x = x + mlp_apply(bp["mlp"], cfg, apply_norm(bp["norm2"], x, cfg.norm))
        new_caches.append(dict(caches[i], self=self_cache))
    x = apply_norm(p["dec_norm"], x, cfg.norm)
    return unembed(p["embed"], x), new_caches
