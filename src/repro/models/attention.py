"""GQA self-attention and cross-attention blocks (train / prefill / decode).

The attention core routes through ``repro.kernels.ops`` so the Pallas flash
kernels are used on TPU and the jnp oracle on CPU. KV caches are explicit
pytrees threaded by the caller (see ``models/kvcache.py``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels import ops
from .layers import Params, apply_mrope, apply_rope, dense, dense_init


def attn_init(key, cfg, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    qd, kvd = cfg.q_dim(), cfg.kv_dim()
    ks = jax.random.split(key, 4)
    dt = _pdtype(cfg)
    return {
        "wq": dense_init(ks[0], d, qd, dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, kvd, dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, kvd, dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], qd, d, dt, bias=False, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def _pdtype(cfg):
    import jax.numpy as _jnp

    return {"bfloat16": _jnp.bfloat16, "float32": _jnp.float32}[cfg.param_dtype]


def _apply_positional(cfg, q, k, positions):
    if cfg.rope_mode == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_mode == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def attn_apply(
    p: Params,
    cfg,
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S) or (B, S, 3) for mrope
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "auto",
) -> jnp.ndarray:
    """Full-sequence self attention (training / prefill)."""
    b, s, _ = x.shape
    hd = cfg.hd()
    q = dense(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.num_kv_heads, hd)
    q, k = _apply_positional(cfg, q, k, positions)
    if cfg.act_shard == "seq":
        # sequence parallelism: q stays seq-sharded (each shard owns a span
        # of query rows); K/V are gathered across "model" — tiny under GQA
        # (kv_heads ≪ heads). Attention output stays seq-sharded, so no
        # layout thrash against the seq-sharded residual stream.
        q = constrain(q, ("pod", "data"), "model", None, None)
        k = constrain(k, ("pod", "data"), None, None, None)
        v = constrain(v, ("pod", "data"), None, None, None)
    else:
        q = constrain(q, ("pod", "data"), None, "model", None)
        k = constrain(k, ("pod", "data"), None, "model", None)
    o = ops.attention(
        q, k, v, causal=causal, window=window,
        softcap=cfg.attn_logit_softcap, impl=impl,
    )
    o = o.reshape(b, s, cfg.q_dim())
    return dense(p["wo"], o)


def attn_prefill(
    p: Params, cfg, x, positions, cache: Dict[str, Any], *,
    causal: bool = True, window: int = 0, impl: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Prefill: same as train but also fills the KV cache."""
    b, s, _ = x.shape
    hd = cfg.hd()
    q = dense(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.num_kv_heads, hd)
    q, k = _apply_positional(cfg, q, k, positions)
    o = ops.attention(
        q, k, v, causal=causal, window=window,
        softcap=cfg.attn_logit_softcap, impl=impl,
    )
    o = o.reshape(b, s, cfg.q_dim())
    new_cache = _cache_write_prefill(cache, k, v, s)
    return dense(p["wo"], o), new_cache


def attn_decode(
    p: Params, cfg, x, positions, cache: Dict[str, Any], *,
    window: int = 0, impl: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode against the cache.

    x: (B, 1, D); cache holds k/v (B, S, KVH, hd) and pos (B,) int32 valid
    lengths. For sliding-window layers the cache length is the window and
    writes wrap (rolling buffer).
    """
    b, s1, _ = x.shape
    assert s1 == 1
    hd = cfg.hd()
    q = dense(p["wq"], x).reshape(b, 1, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    q, k = _apply_positional(cfg, q, k, positions)

    cache_len = cache["k"].shape[1]
    pos = cache["pos"]  # scalar int32: synchronized decode position
    if window > 0:
        slot = jnp.mod(pos, cache_len)
    else:
        slot = jnp.minimum(pos, cache_len - 1)
    k_cache = _write_slot(cache["k"], k[:, 0], slot)
    v_cache = _write_slot(cache["v"], v[:, 0], slot)
    lengths = jnp.broadcast_to(jnp.minimum(pos + 1, cache_len), (b,))
    o = ops.decode_attention(
        q[:, 0], k_cache, v_cache, lengths,
        softcap=cfg.attn_logit_softcap, impl=impl,
    )  # (B, H, hd)
    o = o.reshape(b, 1, cfg.q_dim())
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    return dense(p["wo"], o), new_cache


def cross_attn_apply(
    p: Params, cfg, x, enc_kv: Tuple[jnp.ndarray, jnp.ndarray], *, impl: str = "auto"
) -> jnp.ndarray:
    """Encoder-decoder cross attention; enc_kv are precomputed (B,Se,KVH,hd)."""
    b, s, _ = x.shape
    hd = cfg.hd()
    q = dense(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k, v = enc_kv
    o = ops.attention(q, k, v, causal=False, window=0, impl=impl)
    o = o.reshape(b, s, cfg.q_dim())
    return dense(p["wo"], o)


def cross_kv(p: Params, cfg, enc_out: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, se, _ = enc_out.shape
    hd = cfg.hd()
    k = dense(p["wk"], enc_out).reshape(b, se, cfg.num_kv_heads, hd)
    v = dense(p["wv"], enc_out).reshape(b, se, cfg.num_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------

def _write_slot(cache: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Write (B, KVH, hd) ``new`` at the (scalar) synchronized position.

    A *scalar*-index dynamic_update_slice is the key to a partitionable
    decode step: per-sequence scatter indices force GSPMD into "involuntary
    full rematerialization" (it replicates the whole cache every token —
    measured as the collective bottleneck of every decode cell); a uniform
    slot updates each shard locally with zero collective traffic. Batched
    serving decodes synchronized positions anyway (padded prompts).
    """
    return jax.lax.dynamic_update_slice(
        cache, new[:, None].astype(cache.dtype), (0, slot, 0, 0))


def _cache_write_prefill(cache: Dict[str, Any], k, v, s: int) -> Dict[str, Any]:
    cache_len = cache["k"].shape[1]
    if s >= cache_len:
        # ring alignment: decode writes position p at slot p % cache_len, so
        # the kept tail [s-L, s) must land with position p at slot p % L.
        k_new = jnp.roll(k[:, -cache_len:], shift=s % cache_len, axis=1)
        v_new = jnp.roll(v[:, -cache_len:], shift=s % cache_len, axis=1)
    else:
        pad = cache_len - s
        k_new = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_new = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    b = k.shape[0]
    return {
        "k": k_new.astype(cache["k"].dtype),
        "v": v_new.astype(cache["v"].dtype),
        "pos": jnp.int32(s),
    }
