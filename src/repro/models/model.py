"""Unified model interface: init / forward / prefill / decode for every arch.

``batch`` dicts:
  * LM families:  {"tokens": (B,S) int32[, "positions": (B,S) or (B,S,3)]}
  * vlm:          + {"vision_embeds": (B, Sv, D)} patch embeddings (stub
                  frontend) overwriting the first Sv token embeddings
  * audio (enc-dec): {"frames": (B, Se, D) stub conv output,
                      "tokens": (B,S) decoder tokens}

Decode:
  * ``decode_step(params, cfg, token, positions, cache)`` — one new token
    per sequence against the cache/state pytree from ``init_cache`` /
    ``prefill``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain_batch
from . import encdec, transformer
from .layers import Params, apply_norm, embed, embed_init, norm_init, unembed


def _dt(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init(key, cfg: ModelConfig) -> Params:
    if cfg.is_encoder_decoder:
        return encdec.encdec_init(key, cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    pdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    p: Params = {
        "embed": embed_init(k1, cfg.vocab_size, cfg.d_model, pdt),
        "stack": transformer.stack_init(k2, cfg),
        "final_norm": norm_init(cfg.d_model, cfg.norm, pdt),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(k3, cfg.vocab_size, cfg.d_model, pdt)
    return p


def _positions(cfg, batch: Dict[str, Any]) -> jnp.ndarray:
    if "positions" in batch:
        return batch["positions"]
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


def _embed_inputs(p, cfg, batch) -> jnp.ndarray:
    x = embed(p["embed"], batch["tokens"]).astype(_dt(cfg))
    if cfg.frontend == "vision_stub" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)
        sv = ve.shape[1]
        x = jnp.concatenate([ve, x[:, sv:]], axis=1)
    x = x * (cfg.d_model ** 0.5) if cfg.family == "hybrid" else x  # gemma scaling
    return constrain_batch(x)


def _logits(p, cfg, x) -> jnp.ndarray:
    x = apply_norm(p["final_norm"], x, cfg.norm)
    table = p["head"] if "head" in p else p["embed"]
    return unembed(table, x)


def forward(p: Params, cfg: ModelConfig, batch: Dict[str, Any]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss)."""
    if cfg.is_encoder_decoder:
        enc_out = encdec.encode(p, cfg, batch["frames"].astype(_dt(cfg)))
        logits = encdec.decode_train(p, cfg, batch["tokens"], enc_out)
        return logits, jnp.zeros((), jnp.float32)
    x = _embed_inputs(p, cfg, batch)
    pos = _positions(cfg, batch)
    x, aux = transformer.stack_apply(p["stack"], cfg, x, pos)
    return _logits(p, cfg, x), aux


def hidden_forward(p: Params, cfg: ModelConfig, batch: Dict[str, Any]) -> jnp.ndarray:
    """Forward returning final hidden states (no unembed) — used by the
    chunked-loss training path so the (B,S,V) logits are never materialized."""
    assert not cfg.is_encoder_decoder
    x = _embed_inputs(p, cfg, batch)
    pos = _positions(cfg, batch)
    x, aux = transformer.stack_apply(p["stack"], cfg, x, pos)
    return apply_norm(p["final_norm"], x, cfg.norm), aux


def init_cache(p: Params, cfg: ModelConfig, batch_size: int, cache_len: int,
               enc_out: Optional[jnp.ndarray] = None):
    if cfg.is_encoder_decoder:
        assert enc_out is not None
        return encdec.init_dec_cache(p, cfg, enc_out, batch_size, cache_len, _dt(cfg))
    return transformer.init_cache(cfg, batch_size, cache_len)


def _serving_cfg(cfg: ModelConfig) -> ModelConfig:
    """Serving strips training-only layout choices: sequence-parallel
    activations help train-step memory but regress prefill/decode (measured:
    0.66× on mistral/vl prefill), and the EP-MoE shard_map path loses to the
    global formulation at decode token counts."""
    import dataclasses as _dc

    if cfg.act_shard != "none":
        cfg = _dc.replace(cfg, act_shard="none")
    return cfg


def prefill(p: Params, cfg: ModelConfig, batch: Dict[str, Any], cache_len: int):
    """Run the prompt, return (last-token logits, cache)."""
    cfg = _serving_cfg(cfg)
    if cfg.is_encoder_decoder:
        enc_out = encdec.encode(p, cfg, batch["frames"].astype(_dt(cfg)))
        caches = encdec.init_dec_cache(
            p, cfg, enc_out, batch["tokens"].shape[0], cache_len, _dt(cfg))
        logits = encdec.decode_train(p, cfg, batch["tokens"], enc_out)
        # fill self caches by a decode sweep is wasteful; prefill caches via
        # train-shaped pass is handled inside encdec in a follow-up; for the
        # serving path we reuse decode_step after this point.
        return logits[:, -1:], caches
    x = _embed_inputs(p, cfg, batch)
    pos = _positions(cfg, batch)
    caches = transformer.init_cache(cfg, batch["tokens"].shape[0], cache_len)
    x, caches = transformer.stack_prefill(p["stack"], cfg, x, pos, caches)
    return _logits(p, cfg, x[:, -1:]), caches


def decode_step(p: Params, cfg: ModelConfig, token: jnp.ndarray,
                positions: jnp.ndarray, cache) -> Tuple[jnp.ndarray, Any]:
    """token (B,1) int32; positions (B,1) (or (B,1,3) for mrope)."""
    cfg = _serving_cfg(cfg)
    if cfg.num_experts and cfg.moe_impl == "ep":
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe_impl="gather")
    if cfg.is_encoder_decoder:
        return encdec.decode_step(p, cfg, token, positions[..., 0] if positions.ndim == 3 else positions, cache)
    x = embed(p["embed"], token).astype(_dt(cfg))
    if cfg.family == "hybrid":
        x = x * (cfg.d_model ** 0.5)
    x, cache = transformer.stack_decode(p["stack"], cfg, x, positions, cache)
    return _logits(p, cfg, x), cache
