"""Feed-forward blocks: SwiGLU (silu) and plain GELU MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .layers import GATED_ACTS, Params, activation, dense, dense_init


def mlp_init(key, cfg, d_ff: Optional[int] = None, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 3)
    p: Params = {
        "w_in": dense_init(ks[0], d, f, dt),
        "w_out": dense_init(ks[1], f, d, dt, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }
    if cfg.act in GATED_ACTS:
        p["w_gate"] = dense_init(ks[2], d, f, dt)
    return p


def mlp_apply(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    act = activation(cfg.act)
    h = dense(p["w_in"], x)
    if "w_gate" in p:
        h = act(dense(p["w_gate"], x)) * h
    else:
        h = act(h)
    if getattr(cfg, "act_shard", "none") == "seq" and h.ndim == 3:
        # sequence parallelism: the FFN is token-local — keep tokens sharded
        h = constrain(h, ("pod", "data"), "model", None)
    else:
        h = constrain(h, ("pod", "data"), None, "model")
    return dense(p["w_out"], h)
