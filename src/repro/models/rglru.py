"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)  with
a_t = exp(-c · softplus(Λ) · σ(W_r x_t))  is a first-order linear scan:
train/prefill use `jax.lax.associative_scan` (log-depth, TPU-friendly),
decode is an O(1) update — which is what makes the 0.5M-token long-context
cell runnable for this architecture.

Block layout (Griffin recurrent block): pre-norm → {gate branch: linear+GeLU}
⊙ {recurrent branch: linear → causal conv(4) → RG-LRU} → output linear.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense, dense_init
from .xlstm import _causal_conv, _conv_step, conv_tail_buffer

A_SCALE = 8.0  # the paper's c constant


def rglru_init(key, cfg) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 6)
    # Λ init so that a^c spans roughly (0.9, 0.999) as in the paper
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / A_SCALE))  # softplus^-1
    return {
        "w_x": dense_init(ks[1], d, w, dt),
        "w_gate_branch": dense_init(ks[2], d, w, dt),
        "conv": {"w": jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32).astype(dt) * 0.1},
        "w_rec_gate": dense_init(ks[4], w, 2 * w, jnp.float32, bias=True),  # r and i gates
        "lambda": lam,
        "w_out": dense_init(ks[5], w, d, dt, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def _gates(p: Params, xw: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Recurrence (r) and input (i) gates + log coefficient.

    xw: (..., w) the post-conv recurrent-branch activations (fp32 math).
    Returns (log_a, gated_input) with log_a = -c·softplus(Λ)·σ(r).
    """
    g = dense(p["w_rec_gate"], xw.astype(jnp.float32))
    w = xw.shape[-1]
    r, i = g[..., :w], g[..., w:]
    log_a = -A_SCALE * jax.nn.softplus(p["lambda"]) * jax.nn.sigmoid(r)
    gated = jax.nn.sigmoid(i) * xw.astype(jnp.float32)
    # multiplier sqrt(1 - a^2), computed stably via log1p(-exp(2 log a))
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return log_a, mult * gated


def rglru_scan(log_a: jnp.ndarray, b: jnp.ndarray, h0=None) -> jnp.ndarray:
    """Associative scan for h_t = a_t h_{t-1} + b_t over axis 1.

    log_a, b: (B, S, W) fp32. h0: optional (B, W) entering state.
    """
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(p: Params, cfg, x: jnp.ndarray, state=None, return_state: bool = False):
    """Full-sequence Griffin recurrent block body. x (B,S,D)."""
    gate = jax.nn.gelu(dense(p["w_gate_branch"], x))
    xr = dense(p["w_x"], x)
    conv = _causal_conv(xr, p["conv"]["w"])
    log_a, binp = _gates(p, conv)
    h0 = state["h"] if state is not None else None
    h = rglru_scan(log_a, binp, h0=h0).astype(x.dtype)
    y = dense(p["w_out"], h * gate)
    if return_state:
        new_state = {
            "h": rglru_final_state(log_a, binp, h),
            "conv_buf": conv_tail_buffer(xr, p["conv"]["w"].shape[0]),
        }
        return y, new_state
    return y


def rglru_final_state(log_a, binp, h) -> jnp.ndarray:
    return h[:, -1].astype(jnp.float32)


def rglru_decode(p: Params, cfg, x_t: jnp.ndarray, state: Dict[str, Any]):
    """One-token step. x_t (B,1,D); state {h (B,W) fp32, conv_buf}."""
    xt = x_t[:, 0]
    gate = jax.nn.gelu(dense(p["w_gate_branch"], xt))
    xr = dense(p["w_x"], xt)
    conv_out, conv_buf = _conv_step(xr, p["conv"]["w"], state["conv_buf"])
    log_a, binp = _gates(p, conv_out)
    h_new = jnp.exp(log_a) * state["h"] + binp
    y = dense(p["w_out"], (h_new.astype(x_t.dtype) * gate))[:, None]
    return y, {"h": h_new, "conv_buf": conv_buf}


def rglru_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv_buf": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }
