"""Decoder-only LM assembly: mixed block kinds, layer-scan + remat, caches.

Uniform-attention architectures (all dense + MoE LMs) stack their layers as
a scanned pytree — `jax.lax.scan` over stacked params keeps the HLO O(1) in
depth and composes with `jax.checkpoint` for remat. Hybrid/SSM architectures
(xLSTM, RecurrentGemma) have heterogeneous per-layer params and are unrolled
(12–38 layers: small HLO either way).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ATTN, MLSTM, RECUR, SLSTM
from ..distributed.sharding import constrain
from . import attention as attn
from . import moe as moe_mod
from .layers import Params, apply_norm, norm_init
from .mlp import mlp_apply, mlp_init
from .rglru import rglru_apply, rglru_decode, rglru_init, rglru_state_init
from .xlstm import (
    mlstm_apply,
    mlstm_decode,
    mlstm_init,
    mlstm_state_init,
    slstm_apply,
    slstm_decode,
    slstm_init,
    slstm_state_init,
)


def _dt(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    pdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    p: Params = {"norm1": norm_init(cfg.d_model, cfg.norm, pdt)}
    if kind == ATTN:
        p["attn"] = attn.attn_init(ks[0], cfg)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, pdt)
        if cfg.num_experts:
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        elif cfg.d_ff:
            p["mlp"] = mlp_init(ks[1], cfg)
    elif kind == RECUR:
        p["recur"] = rglru_init(ks[0], cfg)
        if cfg.d_ff:
            p["norm2"] = norm_init(cfg.d_model, cfg.norm, pdt)
            p["mlp"] = mlp_init(ks[1], cfg)
    elif kind == MLSTM:
        p["mlstm"] = mlstm_init(ks[0], cfg)
    elif kind == SLSTM:
        p["slstm"] = slstm_init(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    return p


def _layer_window(cfg, kind: str) -> int:
    # hybrid archs use windowed local attention for their ATTN layers
    return cfg.attn_window if kind == ATTN else 0


def block_apply(
    p: Params, cfg, kind: str, x: jnp.ndarray, positions: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training / no-cache forward. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == ATTN:
        x = x + attn.attn_apply(p["attn"], cfg, h, positions,
                                causal=True, window=_layer_window(cfg, kind))
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            moe_fn = moe_mod.moe_apply_ep if cfg.moe_impl == "ep" else moe_mod.moe_apply
            y, aux = moe_fn(p["moe"], cfg, h2)
            x = x + y
        elif "mlp" in p:
            x = x + mlp_apply(p["mlp"], cfg, h2)
    elif kind == RECUR:
        x = x + rglru_apply(p["recur"], cfg, h)
        if "mlp" in p:
            x = x + mlp_apply(p["mlp"], cfg, apply_norm(p["norm2"], x, cfg.norm))
    elif kind == MLSTM:
        x = x + mlstm_apply(p["mlstm"], cfg, h)
    elif kind == SLSTM:
        x = x + slstm_apply(p["slstm"], cfg, h)
    if cfg.act_shard == "seq":
        x = constrain(x, ("pod", "data"), "model", None)
    else:
        x = constrain(x, ("pod", "data"), None, None)
    return x, aux


def block_prefill(p, cfg, kind, x, positions, cache):
    """Forward that also produces a decode cache for this layer."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == ATTN:
        y, new_cache = attn.attn_prefill(
            p["attn"], cfg, h, positions, cache,
            causal=True, window=_layer_window(cfg, kind))
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            moe_fn = moe_mod.moe_apply_ep if cfg.moe_impl == "ep" else moe_mod.moe_apply
            y2, _ = moe_fn(p["moe"], cfg, h2)
            x = x + y2
        elif "mlp" in p:
            x = x + mlp_apply(p["mlp"], cfg, h2)
    elif kind == RECUR:
        y, new_cache = rglru_apply(p["recur"], cfg, h, return_state=True)
        x = x + y
        if "mlp" in p:
            x = x + mlp_apply(p["mlp"], cfg, apply_norm(p["norm2"], x, cfg.norm))
    elif kind == MLSTM:
        y, new_cache = mlstm_apply(p["mlstm"], cfg, h, return_state=True)
        x = x + y
    elif kind == SLSTM:
        raise NotImplementedError("sLSTM prefill-with-state uses the scan path")
    x = constrain(x, ("pod", "data"), None, None)
    return x, new_cache


def block_decode(p, cfg, kind, x, positions, cache):
    """One-token step. x (B,1,D)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == ATTN:
        y, new_cache = attn.attn_decode(
            p["attn"], cfg, h, positions, cache, window=_layer_window(cfg, kind))
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            moe_fn = moe_mod.moe_apply_ep if cfg.moe_impl == "ep" else moe_mod.moe_apply
            y2, _ = moe_fn(p["moe"], cfg, h2, capacity_factor=2.0)
            x = x + y2
        elif "mlp" in p:
            x = x + mlp_apply(p["mlp"], cfg, h2)
    elif kind == RECUR:
        y, new_cache = rglru_decode(p["recur"], cfg, h, cache)
        x = x + y
        if "mlp" in p:
            x = x + mlp_apply(p["mlp"], cfg, apply_norm(p["norm2"], x, cfg.norm))
    elif kind == MLSTM:
        y, new_cache = mlstm_decode(p["mlstm"], cfg, h, cache)
        x = x + y
    elif kind == SLSTM:
        y, new_cache = slstm_decode(p["slstm"], cfg, h, cache)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------

def _uniform(cfg) -> bool:
    return cfg.scan_layers and set(cfg.pattern()) == {ATTN}


def stack_init(key, cfg) -> Params:
    if _uniform(cfg):
        keys = jax.random.split(key, cfg.num_layers)
        stacked = jax.vmap(lambda k: block_init(k, cfg, ATTN))(keys)
        return {"layers": stacked}
    blocks = {}
    pattern = cfg.pattern()
    keys = jax.random.split(key, cfg.num_layers)
    for i, kind in enumerate(pattern):
        blocks[str(i)] = block_init(keys[i], cfg, kind)
    return {"blocks": blocks}


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full


def stack_apply(p: Params, cfg, x, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if "layers" in p:
        def body(carry, lp):
            h, aux = carry
            h, aux_i = block_apply(lp, cfg, ATTN, h, positions)
            return (h, aux + aux_i), None

        (x, aux), _ = jax.lax.scan(
            _remat(body, cfg), (x, jnp.zeros((), jnp.float32)), p["layers"]
        )
        return x, aux
    aux = jnp.zeros((), jnp.float32)
    pattern = cfg.pattern()
    for i, kind in enumerate(pattern):
        fn = _remat(functools.partial(block_apply, p["blocks"][str(i)], cfg, kind), cfg)
        x, aux_i = fn(x, positions)
        aux = aux + aux_i
    return x, aux


def stack_prefill(p: Params, cfg, x, positions, caches):
    if "layers" in p:
        def body(carry, inp):
            lp, cache = inp
            y, new_cache = block_prefill(lp, cfg, ATTN, carry, positions, cache)
            return y, new_cache
        x, new_caches = jax.lax.scan(body, x, (p["layers"], caches))
        return x, new_caches
    new_caches = []
    for i, kind in enumerate(cfg.pattern()):
        if kind == SLSTM:
            # sequential state: run scan-based prefill (slow path, exactness)
            x, cache = _slstm_prefill(p["blocks"][str(i)], cfg, x, caches[i])
        else:
            x, cache = block_prefill(p["blocks"][str(i)], cfg, kind, x, positions, caches[i])
        new_caches.append(cache)
    return x, new_caches


def _slstm_prefill(bp, cfg, x, cache):
    from .xlstm import slstm_cell
    from .layers import dense

    h = apply_norm(bp["norm1"], x, cfg.norm)
    gx = dense(bp["slstm"]["w"], h)

    def step(state, gx_t):
        new = slstm_cell(bp["slstm"], cfg, gx_t, state)
        return new, new["h"]

    final, hs = jax.lax.scan(step, cache, gx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)
    y = dense(bp["slstm"]["w_down"], jax.nn.gelu(dense(bp["slstm"]["w_up"], hs)))
    return x + y, final


def stack_decode(p: Params, cfg, x, positions, caches):
    if "layers" in p:
        def body(carry, inp):
            lp, cache = inp
            y, new_cache = block_decode(lp, cfg, ATTN, carry, positions, cache)
            return y, new_cache
        x, new_caches = jax.lax.scan(body, x, (p["layers"], caches))
        return x, new_caches
    new_caches = []
    for i, kind in enumerate(cfg.pattern()):
        x, cache = block_decode(p["blocks"][str(i)], cfg, kind, x, positions, caches[i])
        new_caches.append(cache)
    return x, new_caches


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _attn_cache_init(cfg, batch: int, cache_len: int, dtype) -> Dict[str, Any]:
    length = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    return {
        "k": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.hd()), dtype),
        "v": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.hd()), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    dtype = dtype or _dt(cfg)
    if _uniform(cfg):
        one = _attn_cache_init(cfg, batch, cache_len, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape).copy(), one
        )
    caches: List[Any] = []
    for kind in cfg.pattern():
        if kind == ATTN:
            caches.append(_attn_cache_init(cfg, batch, cache_len, dtype))
        elif kind == RECUR:
            caches.append(rglru_state_init(cfg, batch, dtype))
        elif kind == MLSTM:
            caches.append(mlstm_state_init(cfg, batch, dtype))
        elif kind == SLSTM:
            caches.append(slstm_state_init(cfg, batch, dtype))
    return caches
