"""Mixture-of-Experts FFN: top-k routing with capacity, expert-parallel.

Two dispatch implementations:

* ``gather`` (default): tokens are placed into per-expert slots with a
  scatter, expert FFNs run as one batched einsum over (E, C, d), results
  come back with a gather. Zero "fake" FLOPs — the HLO FLOP count equals
  active-expert compute, which keeps the roofline's MODEL_FLOPS/HLO_FLOPs
  ratio honest. Dropped tokens (beyond capacity) lose their expert
  contribution, standard GShard behaviour.
* ``einsum``: classic GShard one-hot dispatch/combine einsums. More
  collective-friendly under some partitioners but adds B·S·E·C·d dispatch
  FLOPs; kept for A/B tests in §Perf.

Expert weights are stacked (E, d, f) so GSPMD shards the expert dim over
the "model" axis (expert parallelism) when E divides it, else the ffn width.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed import sharding as shd
from ..distributed.sharding import constrain
from .layers import Params, activation, dense_init
from .mlp import mlp_apply, mlp_init


def moe_init(key, cfg) -> Params:
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)

    def stack(k, shape):
        return (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32) * std).astype(dt)

    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "experts": {
            "w_in": {"w": stack(ks[1], (e, d, f))},
            "w_gate": {"w": stack(ks[2], (e, d, f))},
            "w_out": {"w": stack(ks[3], (e, f, d)) * (1.0 / max(1, cfg.num_layers) ** 0.5)},
        },
    }
    if cfg.num_shared_experts:
        shared_f = (cfg.shared_d_ff or f) * cfg.num_shared_experts
        p["shared"] = mlp_init(ks[4], cfg, d_ff=shared_f)
    if cfg.moe_dense_residual:
        p["dense"] = mlp_init(ks[5], cfg, d_ff=cfg.moe_dense_d_ff or f)
    return p


def _route(p: Params, cfg, x2d: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router: returns (weights (T,k), experts (T,k), probs (T,E))."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, sel, probs


def aux_load_balance(probs: jnp.ndarray, sel: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Switch-style load balancing loss: E * sum_e f_e * P_e."""
    counts = jnp.zeros((num_experts,), jnp.float32)
    onehot = jax.nn.one_hot(sel, num_experts, dtype=jnp.float32)  # (T, k, E)
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f_e * p_e)


def _expert_ffn(experts: Params, cfg, h_in: jnp.ndarray) -> jnp.ndarray:
    """Batched per-expert FFN over (E, C, d)."""
    act = activation(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", h_in, experts["w_in"]["w"])
    gate = jnp.einsum("ecd,edf->ecf", h_in, experts["w_gate"]["w"])
    h = act(gate) * up
    h = constrain(h, "model", None, None)
    return jnp.einsum("ecf,efd->ecd", h, experts["w_out"]["w"])


def moe_apply(
    p: Params,
    cfg,
    x: jnp.ndarray,  # (B, S, D)
    *,
    capacity_factor: float = 1.25,
    impl: str = "gather",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux load-balance loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    x2d = x.reshape(t, d)
    weights, sel, probs = _route(p, cfg, x2d)
    aux = aux_load_balance(probs, sel, e)

    cap = int(math.ceil(t * k * capacity_factor / e))
    cap = max(cap, 1)

    flat_sel = sel.reshape(t * k)  # expert id per (token, choice)
    onehot = jax.nn.one_hot(flat_sel, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot  # exclusive count
    pos = jnp.take_along_axis(pos_in_expert, flat_sel[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < cap

    if impl == "einsum":
        # GShard dispatch/combine one-hot tensors.
        disp = (
            jax.nn.one_hot(flat_sel, e, dtype=x.dtype)[:, :, None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype)[:, None, :cap]
        ).reshape(t, k, e, cap)
        expert_in = jnp.einsum("tkec,td->ecd", disp, x2d)
        expert_out = _expert_ffn(p["experts"], cfg, expert_in)
        comb = disp * weights.astype(x.dtype)[:, :, None, None]
        y2d = jnp.einsum("tkec,ecd->td", comb, expert_out)
    else:
        token_ids = jnp.arange(t * k, dtype=jnp.int32) // k  # token of each choice
        # slot_owner[e, c] = flat token index occupying that slot (t = pad row)
        slot_owner = jnp.full((e, cap), t, jnp.int32)
        # dropped (token, choice) pairs scatter to row index ``e`` which is out
        # of bounds and silently dropped — they never clobber a live slot.
        slot_owner = slot_owner.at[
            jnp.where(keep, flat_sel, e),
            jnp.where(keep, pos, 0),
        ].set(token_ids, mode="drop")
        x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
        expert_in = x_pad[slot_owner]  # (E, C, D) gather
        expert_in = constrain(expert_in, "model", None, None)
        expert_out = _expert_ffn(p["experts"], cfg, expert_in)  # (E, C, D)
        # combine: each (token, choice) reads its slot back
        safe_pos = jnp.where(keep, pos, 0)
        out_choice = expert_out[flat_sel, safe_pos]  # (T*k, D)
        out_choice = jnp.where(keep[:, None], out_choice, 0.0)
        y2d = jnp.sum(
            out_choice.reshape(t, k, d) * weights.astype(x.dtype)[:, :, None], axis=1
        )

    y = y2d.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], cfg, x)
    if "dense" in p:
        y = y + mlp_apply(p["dense"], cfg, x)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# expert-parallel MoE (shard_map): the production path under a mesh
# ---------------------------------------------------------------------------
#
# GSPMD cannot partition the global gather/scatter dispatch — it replicates
# the expert computation on every device (measured: useful-compute ratio
# 0.011 on qwen2-moe × train_4k). The shard_map formulation makes the
# parallelism explicit and collective-minimal:
#
#   * tokens stay sharded over the DP axes ("pod","data") and are REPLICATED
#     over "model" — so no token all-to-all is needed at all;
#   * experts are sharded over "model" (padded up to a multiple of its size;
#     padded experts get -inf router logits and are never selected);
#   * every (data, model) shard routes its local tokens, runs only its own
#     E/|model| experts, and one psum over "model" combines the results —
#     the same collective class as Megatron TP, amortized over k≪E experts.
#
# Per-device expert FLOPs = global_expert_FLOPs / (|data|·|model|), vs the
# global formulation's ≈ global_expert_FLOPs (replicated).

def _pad_experts(p: Params, e_pad: int, e: int):
    if e_pad == e:
        return p["experts"], p["router"]["w"]
    def padw(w):
        pad = jnp.zeros((e_pad - e,) + w.shape[1:], w.dtype)
        return jnp.concatenate([w, pad], axis=0)
    experts = {k: {"w": padw(v["w"])} for k, v in p["experts"].items()}
    rw = jnp.concatenate(
        [p["router"]["w"], jnp.full((p["router"]["w"].shape[0], e_pad - e), 0.0,
                                    p["router"]["w"].dtype)], axis=1)
    return experts, rw


def moe_apply_ep(
    p: Params,
    cfg,
    x: jnp.ndarray,  # (B, S, D)
    *,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE under the active mesh; falls back to the global
    formulation when un-meshed or the batch does not divide the DP axes."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = shd.active_mesh()
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    if mesh is None or "model" not in mesh.axis_names:
        return moe_apply(p, cfg, x, capacity_factor=capacity_factor)
    msize = shd.axis_size(mesh, "model")
    dp_axes = shd.mesh_batch_axes(mesh)
    while dp_axes and b % shd.axis_size(mesh, dp_axes) != 0:
        dp_axes = dp_axes[1:]
    dp = shd.axis_size(mesh, dp_axes) if dp_axes else 1
    e_pad = ((e + msize - 1) // msize) * msize
    e_loc = e_pad // msize
    t_loc = (b // dp) * s
    cap = max(int(math.ceil(t_loc * k * capacity_factor / e_pad)), 1)

    experts, rw = _pad_experts(p, e_pad, e)
    act = activation(cfg.act)

    def local_fn(xl, rw_, w_in, w_gate, w_out):
        m_idx = jax.lax.axis_index("model")
        bl, s_, d_ = xl.shape
        t = bl * s_
        x2 = xl.reshape(t, d_)
        logits = x2.astype(jnp.float32) @ rw_.astype(jnp.float32)
        if e_pad != e:  # padded experts are unroutable
            logits = logits.at[:, e:].set(-1e9)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, sel = jax.lax.top_k(probs, k)
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
        aux = aux_load_balance(probs[:, :e], jnp.minimum(sel, e - 1), e)
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux

        flat_sel = sel.reshape(t * k)
        onehot = jax.nn.one_hot(flat_sel, e_pad, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                                  flat_sel[:, None], axis=1)[:, 0]
        keep = pos < cap
        lo = m_idx * e_loc
        mine = keep & (flat_sel >= lo) & (flat_sel < lo + e_loc)
        local_e = flat_sel - lo
        token_ids = jnp.arange(t * k, dtype=jnp.int32) // k
        slot_owner = jnp.full((e_loc, cap), t, jnp.int32)
        slot_owner = slot_owner.at[
            jnp.where(mine, local_e, e_loc), jnp.where(mine, pos, 0)
        ].set(token_ids, mode="drop")
        x_pad = jnp.concatenate([x2, jnp.zeros((1, d_), x2.dtype)], axis=0)
        expert_in = x_pad[slot_owner]  # (E_loc, C, D) all local
        up = jnp.einsum("ecd,edf->ecf", expert_in, w_in)
        gate = jnp.einsum("ecd,edf->ecf", expert_in, w_gate)
        h = act(gate) * up
        expert_out = jnp.einsum("ecf,efd->ecd", h, w_out)
        safe_e = jnp.where(mine, local_e, 0)
        safe_p = jnp.where(mine, pos, 0)
        out_choice = expert_out[safe_e, safe_p]
        out_choice = jnp.where(mine[:, None], out_choice, 0.0)
        y = jnp.sum(out_choice.reshape(t, k, d_) * weights.astype(x2.dtype)[:, :, None], axis=1)
        y = jax.lax.psum(y, "model")
        return y.reshape(bl, s_, d_), aux

    dp_spec = dp_axes if dp_axes else None
    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False,
    )(x, rw, experts["w_in"]["w"], experts["w_gate"]["w"], experts["w_out"]["w"])

    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], cfg, x)
    if "dense" in p:
        y = y + mlp_apply(p["dense"], cfg, x)
    return y, aux
