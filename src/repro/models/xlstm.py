"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar).

mLSTM is attention-free and parallelizable: training/prefill use the
stabilized quadratic "parallel form" (a decay-masked QK^T — structurally a
flash-attention-like computation, which is why this family still benefits
from the MXU); decode is an O(1) recurrent update on a (H, dh, dh) matrix
memory — this is what makes the 0.5M-token `long_500k` cell runnable.

sLSTM has true sequential recurrence (h_{t-1} enters the gates), implemented
with `jax.lax.scan` over time; its state is O(H·dh) per token stream.

Both follow the paper's pre-LN residual block layout with projection factor
2 (mLSTM) and a gated output. Exponential gating uses the m-stabilizer from
the paper, all gate math in fp32.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import Params, dense, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg) -> Params:
    d = cfg.d_model
    inner = 2 * d
    h = cfg.num_heads
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, inner, dt),
        "w_z": dense_init(ks[1], d, inner, dt),
        "wq": dense_init(ks[2], inner, inner, dt),
        "wk": dense_init(ks[3], inner, inner, dt),
        "wv": dense_init(ks[4], inner, inner, dt),
        "w_if": dense_init(ks[5], inner, 2 * h, jnp.float32, bias=True),
        "conv": {"w": jax.random.normal(ks[6], (cfg.conv_width, inner), jnp.float32).astype(dt) * 0.1},
        "w_down": dense_init(ks[7], inner, d, dt, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, state: jnp.ndarray = None):
    """Depthwise causal conv along time. x (B,S,C), w (W,C)."""
    wdt = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wdt - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(wdt))
    return out


def _conv_step(x_t: jnp.ndarray, w: jnp.ndarray, buf: jnp.ndarray):
    """Single decode step. x_t (B,C); buf (B,W-1,C) past inputs."""
    full = jnp.concatenate([buf, x_t[:, None]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", full, w)
    return out, full[:, 1:]


def _split_heads(x, h):
    b, s, inner = x.shape
    return x.reshape(b, s, h, inner // h)


def mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilized parallel (quadratic) mLSTM form.

    q,k,v: (B,S,H,dh); log_i/log_f: (B,S,H) fp32.
    Returns h_tilde (B,S,H,dh).
    """
    b, s, h, dh = q.shape
    lf_cum = jnp.cumsum(log_f, axis=1)  # (B,S,H) F_t = sum_{u<=t} log f_u
    # D[t, u] = exp(F_t - F_u + log_i_u) for u <= t  (contribution of step u at t)
    dmat = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + log_i[:, None, :, :]
    tmask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(tmask[None, :, :, None], dmat, NEG_INF)  # (B,T,U,H)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B,T,1,H) stabilizer
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bthd,buhd->btuh", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / math.sqrt(dh)
    w = scores * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0]))  # (B,T,H)
    out = jnp.einsum("btuh,buhd->bthd", w, v.astype(jnp.float32))
    out = out / (norm[..., None] + 1e-6)
    return out.astype(q.dtype)


def mlstm_step(state: Dict[str, jnp.ndarray], q, k, v, log_i, log_f):
    """O(1) recurrent update. q,k,v: (B,H,dh); gates (B,H).

    state: C (B,H,dh,dh), n (B,H,dh), m (B,H).
    """
    dh = q.shape[-1]
    m_prev, c_prev, n_prev = state["m"], state["C"], state["n"]
    m_new = jnp.maximum(log_f + m_prev, log_i)
    i_ = jnp.exp(log_i - m_new)
    f_ = jnp.exp(log_f + m_prev - m_new)
    kf = k.astype(jnp.float32) / math.sqrt(dh)
    c_new = f_[..., None, None] * c_prev + i_[..., None, None] * (
        v.astype(jnp.float32)[..., :, None] * kf[..., None, :]
    )  # C[d_v, d_k]
    n_new = f_[..., None] * n_prev + i_[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", c_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf)), jnp.exp(-m_new))
    h = num / (den[..., None] + 1e-6)
    return {"C": c_new, "n": n_new, "m": m_new}, h.astype(q.dtype)


def _mlstm_qk_gates(p: Params, cfg, x_in: jnp.ndarray):
    """Shared projection path: x_in (B,S,inner) post-conv activations."""
    h = cfg.num_heads
    q = _split_heads(dense(p["wq"], x_in), h)
    k = _split_heads(dense(p["wk"], x_in), h)
    gates = dense(p["w_if"], x_in.astype(jnp.float32))  # (B,S,2H)
    log_i = gates[..., :h]  # exponential input gate: log i = pre-activation
    log_f = jax.nn.log_sigmoid(gates[..., h:])
    return q, k, log_i, log_f


def mlstm_sequence(q, k, v, log_i, log_f, state0=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM: O(S·chunk) memory, exact math.

    Within a chunk the stabilized quadratic form runs on the MXU; across
    chunks the (C, n, m) matrix-memory state is carried by a scan. This is
    the TPU-native adaptation (VMEM-sized tiles, no S×S materialization) and
    is what makes 32k-token prefill lowerable.

    q,k,v: (B,S,H,dh); gates (B,S,H) fp32. Returns (out, final_state).
    """
    b, s, h, dh = q.shape
    k = k / math.sqrt(dh)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=NEG_INF)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    def to_chunks(a):
        return a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc_all, kc_all, vc_all = to_chunks(q), to_chunks(k), to_chunks(v)
    li_all, lf_all = to_chunks(log_i), to_chunks(log_f)

    if state0 is None:
        state0 = {
            "C": jnp.zeros((b, h, dh, dh), jnp.float32),
            "n": jnp.zeros((b, h, dh), jnp.float32),
            "m": jnp.full((b, h), -1e9, jnp.float32),
        }

    tmask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_fn(carry, inp):
        c0, n0, m0 = carry["C"], carry["n"], carry["m"]
        qc, kc, vc, li, lf = inp
        qf, kf, vf = (a.astype(jnp.float32) for a in (qc, kc, vc))
        fcum = jnp.cumsum(lf, axis=1)  # (B,W,H) inclusive
        dmat = fcum[:, :, None] - fcum[:, None] + li[:, None]  # (B,t,u,H)
        dmat = jnp.where(tmask[None, :, :, None], dmat, NEG_INF)
        e_t = fcum + m0[:, None]  # (B,W,H) weight of entering state at t
        m_t = jnp.maximum(jnp.max(dmat, axis=2), e_t)  # (B,W,H)
        dexp = jnp.exp(dmat - m_t[:, :, None])
        scores = jnp.einsum("bthd,buhd->btuh", qf, kf) * dexp
        inter_w = jnp.exp(e_t - m_t)  # (B,W,H)
        num = jnp.einsum("btuh,buhd->bthd", scores, vf)
        num += jnp.einsum("bhvk,bthk->bthv", c0, qf) * inter_w[..., None]
        den = jnp.sum(scores, axis=2) + jnp.einsum("bhk,bthk->bth", n0, qf) * inter_w
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        out = (num / (den[..., None] + 1e-6)).astype(qc.dtype)
        # state to next chunk
        f_last = fcum[:, -1]  # (B,H)
        m_new = jnp.maximum(m0 + f_last, jnp.max(f_last[:, None] - fcum + li, axis=1))
        decay = jnp.exp(m0 + f_last - m_new)
        per_u = jnp.exp(f_last[:, None] - fcum + li - m_new[:, None])  # (B,W,H)
        c_new = decay[..., None, None] * c0 + jnp.einsum("buh,buhv,buhk->bhvk", per_u, vf, kf)
        n_new = decay[..., None] * n0 + jnp.einsum("buh,buhk->bhk", per_u, kf)
        return {"C": c_new, "n": n_new, "m": m_new}, out

    final_state, outs = jax.lax.scan(chunk_fn, state0, (qc_all, kc_all, vc_all, li_all, lf_all))
    out = outs.swapaxes(0, 1).reshape(b, sp, h, dh)[:, :s]
    return out, final_state


def mlstm_apply(p: Params, cfg, x: jnp.ndarray, state0=None, return_state: bool = False):
    """Full-sequence mLSTM block body (after the outer norm). x (B,S,D)."""
    up = dense(p["w_up"], x)
    z = dense(p["w_z"], x)
    conv = jax.nn.silu(_causal_conv(up, p["conv"]["w"]))
    q, k, log_i, log_f = _mlstm_qk_gates(p, cfg, conv)
    v = _split_heads(up, cfg.num_heads)  # values from the pre-conv stream
    ht, state = mlstm_sequence(q, k, v, log_i, log_f, state0=state0,
                               chunk=getattr(cfg, "mlstm_chunk", 256))
    b, s, _, _ = ht.shape
    out = ht.reshape(b, s, -1) * jax.nn.silu(z)
    y = dense(p["w_down"], out)
    if return_state:
        conv_buf = conv_tail_buffer(up, p["conv"]["w"].shape[0])
        return y, dict(state, conv_buf=conv_buf)
    return y


def conv_tail_buffer(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """Last width-1 inputs, for continuing a causal conv at decode time."""
    b, s, c = x.shape
    need = width - 1
    if s >= need:
        return x[:, s - need :]
    return jnp.pad(x, ((0, 0), (need - s, 0), (0, 0)))


def mlstm_decode(p: Params, cfg, x_t: jnp.ndarray, state: Dict[str, Any]):
    """One-token step. x_t (B,1,D); state {C,n,m,conv_buf}."""
    xt = x_t[:, 0]
    up = dense(p["w_up"], x_t)[:, 0]
    z = dense(p["w_z"], x_t)[:, 0]
    conv_out, conv_buf = _conv_step(up, p["conv"]["w"], state["conv_buf"])
    conv_out = jax.nn.silu(conv_out)
    h = cfg.num_heads
    inner = up.shape[-1]
    dh = inner // h
    q = dense(p["wq"], conv_out).reshape(-1, h, dh)
    k = dense(p["wk"], conv_out).reshape(-1, h, dh)
    gates = dense(p["w_if"], conv_out.astype(jnp.float32))
    log_i, log_f = gates[..., :h], jax.nn.log_sigmoid(gates[..., h:])
    v = up.reshape(-1, h, dh)
    cell_state = {"C": state["C"], "n": state["n"], "m": state["m"]}
    new_cell, ht = mlstm_step(cell_state, q, k, v, log_i, log_f)
    out = ht.reshape(ht.shape[0], inner) * jax.nn.silu(z)
    y = dense(p["w_down"], out)[:, None]
    new_state = dict(new_cell, conv_buf=conv_buf)
    return y, new_state


def mlstm_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    h = cfg.num_heads
    inner = 2 * cfg.d_model
    dh = inner // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e9, jnp.float32),
        "conv_buf": jnp.zeros((batch, cfg.conv_width - 1, inner), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 4)
    # 4 gates (i, f, z, o); recurrent weights are block-diagonal per head.
    w = jax.random.normal(ks[0], (d, 4 * d), jnp.float32) / math.sqrt(d)
    r = jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32) / math.sqrt(dh)
    up_f = int(d * 4 / 3 / 64) * 64 or d
    return {
        "w": {"w": w.astype(dt)},
        "r": {"w": (r * 0.1).astype(dt)},
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_up": dense_init(ks[2], d, up_f, dt),
        "w_down": dense_init(ks[3], up_f, d, dt, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def slstm_cell(p: Params, cfg, gx_t: jnp.ndarray, state: Dict[str, jnp.ndarray]):
    """gx_t: (B, 4D) input-side gate pre-activations at step t."""
    h_heads = cfg.num_heads
    b = gx_t.shape[0]
    d = gx_t.shape[-1] // 4
    dh = d // h_heads
    h_prev = state["h"].reshape(b, h_heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", h_prev.astype(jnp.float32), p["r"]["w"].astype(jnp.float32))
    g = gx_t.astype(jnp.float32) + rec.reshape(b, 4 * d) + p["b"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    m_prev = state["m"]
    m_new = jnp.maximum(gf + m_prev, gi)  # exp forget gate variant
    i_ = jnp.exp(gi - m_new)
    f_ = jnp.exp(gf + m_prev - m_new)
    c_new = f_ * state["c"] + i_ * jnp.tanh(gz)
    n_new = f_ * state["n"] + i_
    h_new = jax.nn.sigmoid(go) * c_new / (n_new + 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Sequential sLSTM over (B,S,D) + gated up/down projection."""
    b, s, d = x.shape
    gx = dense(p["w"], x)  # (B,S,4D) input-side contributions, batched matmul

    def step(state, gx_t):
        new = slstm_cell(p, cfg, gx_t, state)
        return new, new["h"]

    state0 = slstm_state_init(cfg, b)
    _, hs = jax.lax.scan(step, state0, gx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,D)
    return dense(p["w_down"], jax.nn.gelu(dense(p["w_up"], hs)))


def slstm_decode(p: Params, cfg, x_t: jnp.ndarray, state: Dict[str, Any]):
    gx = dense(p["w"], x_t)[:, 0]
    new = slstm_cell(p, cfg, gx, state)
    h = new["h"].astype(x_t.dtype)[:, None]
    y = dense(p["w_down"], jax.nn.gelu(dense(p["w_up"], h)))
    return y, new


def slstm_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }
