"""Core layers: initialization helpers, norms, embeddings, RoPE / M-RoPE.

The module system is deliberately tiny: a "module" is a pair of pure
functions ``init(key, ...) -> params`` and ``apply(params, x, ...) -> y``
over nested-dict pytrees. No global state; dtype policy comes from the
``ModelConfig``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False, scale: float = 1.0) -> Params:
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = scale / (d_in ** 0.5)
    w = (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32) * std)
    p: Params = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype) -> Params:
    w = jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)
    return {"w": w.astype(dtype)}


def embed(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["w"], ids, axis=0)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Project hidden states to logits with the (possibly tied) table."""
    return x @ p["w"].T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype) -> Params:
    p: Params = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard RoPE.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: Tuple[int, ...],
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): three position streams (t, h, w) rotate
    disjoint sections of the head dim.

    x: (..., seq, heads, head_dim); positions: (..., seq, 3) integer ids.
    ``sections`` are sizes in *pairs* summing to head_dim // 2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)  # (hd/2,)
    # Select which position stream drives each frequency pair.
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=hd // 2)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),  # (..., seq, 3)
        jnp.broadcast_to(sec_id, positions.shape[:-1] + (hd // 2,)).astype(jnp.int32),
        axis=-1,
    )  # (..., seq, hd/2)
    ang = pos * inv
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings, shape (seq, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = pos * div
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "geglu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


GATED_ACTS = ("silu", "geglu")  # SwiGLU / GeGLU
