"""End-to-end training driver (deliverable b's "train a ~100M model").

Wires together every substrate layer: config registry → model init on a
mesh → deterministic data pipeline → jitted train step (donated state) →
checkpoint manager (atomic, resumable) → fault-tolerant supervisor loop
(restores and replays bitwise-identically after a failure).

CPU-runnable out of the box:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1
Resume after interruption is automatic (same command).
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Optional

import jax

from ..checkpoint.manager import CheckpointManager
from ..configs import get_config
from ..data.tokens import TokenPipeline
from ..distributed import sharding as shd
from ..distributed.fault_tolerance import HeartbeatMonitor
from ..optim.adamw import AdamWConfig
from ..train.step import TrainState, init_train_state, train_step
from .mesh import make_host_mesh


def build(arch: str, smoke: bool, lr: float, quantize_moments: bool):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    opt_cfg = AdamWConfig(lr=lr, quantize_moments=quantize_moments)
    return cfg, opt_cfg


def train_loop(
    *,
    arch: str = "llama3.2-1b",
    smoke: bool = True,
    steps: int = 200,
    batch: int = 8,
    seq: int = 256,
    lr: float = 1e-3,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    save_every: int = 50,
    log_every: int = 10,
    mesh=None,
    fail_at: Optional[int] = None,  # simulate a failure at this step (tests)
) -> Dict[str, Any]:
    cfg, opt_cfg = build(arch, smoke, lr, quantize_moments=False)
    mesh = mesh or make_host_mesh()

    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt_cfg)
    state = TrainState(
        shd.apply_shardings(state.params, mesh),
        jax.tree_util.tree_map(lambda x: x, state.opt),
    )
    pipe = TokenPipeline(cfg, seed=seed + 1, batch=batch, seq=seq)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    start_step = 0
    if mgr is not None:
        latest = mgr.restore_latest({
            "params": jax.eval_shape(lambda: state.params),
            "opt": jax.eval_shape(lambda: state.opt),
        })
        if latest is not None:
            start_step, restored, extra = latest
            state = TrainState(restored["params"], restored["opt"])
            pipe.restore(extra["data"])
            print(f"[train] resumed from step {start_step}")

    jstep = jax.jit(
        functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg),
        donate_argnums=(0,),
    )
    monitor = HeartbeatMonitor(num_workers=1)
    losses = []
    with shd.use_mesh(mesh):
        for step in range(start_step, steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated failure at step {step}")
            t0 = time.time()
            state, metrics = jstep(state, pipe.next())
            monitor.record(0, time.time() - t0)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.2f}s)")
            if mgr is not None and (step + 1) % save_every == 0:
                mgr.save(step + 1, {"params": state.params, "opt": state.opt},
                         extra={"data": pipe.state()})
    return {"state": state, "losses": losses, "final_step": steps,
            "stragglers": monitor.stragglers()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()
    res = train_loop(arch=args.arch, smoke=args.smoke, steps=args.steps,
                     batch=args.batch, seq=args.seq, lr=args.lr,
                     seed=args.seed, ckpt_dir=args.ckpt_dir,
                     save_every=args.save_every)
    print(f"[train] done. first loss {res['losses'][0]:.4f} -> last {res['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
