"""Production mesh definitions.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state. The single-pod mesh is
16×16 = 256 chips (TPU v5e pod); multi-pod adds a leading "pod" axis:
2×16×16 = 512 chips. Axis roles:

  pod   — pure data parallelism across pods (DCI-connected; the gradient
          compression path targets this axis),
  data  — data parallelism + FSDP parameter storage within a pod,
  model — tensor / expert parallelism (ICI-connected ring).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh() -> Mesh:
    """Single-device mesh for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
