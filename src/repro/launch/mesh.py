"""Production mesh definitions.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state. The single-pod mesh is
16×16 = 256 chips (TPU v5e pod); multi-pod adds a leading "pod" axis:
2×16×16 = 512 chips. Axis roles:

  pod   — pure data parallelism across pods (DCI-connected; the gradient
          compression path targets this axis),
  data  — data parallelism + FSDP parameter storage within a pod,
  model — tensor / expert parallelism (ICI-connected ring).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def _mesh(shape, axes) -> Mesh:
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults every
    # axis to Auto already, so only pass axis_types when it exists.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Single-device mesh for CPU tests."""
    return _mesh((1, 1), ("data", "model"))
