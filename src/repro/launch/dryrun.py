import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first init.
# (This also forces the module docstring below to be a plain expression and
# bans `from __future__ import annotations` here — both are deliberate.)

DOC = """Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell: build the production
mesh (16×16 single pod / 2×16×16 multi-pod), lower the right step function
(train_step / prefill_step / decode_step) against ShapeDtypeStruct inputs
with explicit parameter/batch/cache shardings, ``.compile()`` it, and record
``memory_analysis()`` + ``cost_analysis()`` + the roofline terms.

No real memory is allocated: parameters, optimizer state, batches and KV
caches are all ShapeDtypeStructs via ``jax.eval_shape``.

Usage:
    python -m repro.launch.dryrun --all [--multipod-too] [--out experiments/dryrun]
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multipod
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ARCHS, get_config, input_specs, param_specs_struct
from ..configs.base import ModelConfig, ShapeConfig, shape_applicable
from ..distributed import sharding as shd
from ..optim.adamw import AdamWConfig, OptState
from ..train import step as step_lib
from . import roofline
from .mesh import make_production_mesh

FSDP_THRESHOLD = 5_000_000_000  # params; larger models shard storage over "data"


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    # 480B params + f32 moments exceed one pod's HBM: store moments in bf16.
    if cfg.param_count() > 3e11:
        return AdamWConfig(moment_dtype="bfloat16")
    return AdamWConfig()


def _named(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def state_shardings(params_struct, opt_struct, mesh, fsdp: bool):
    pspecs = shd.param_specs(params_struct, mesh, fsdp=fsdp)
    mu = jax.tree_util.tree_map(lambda s: s, pspecs, is_leaf=lambda x: isinstance(x, P))
    return step_lib.TrainState(
        params=_named(pspecs, mesh),
        opt=OptState(
            step=NamedSharding(mesh, P()),
            mu=_named(mu, mesh),
            nu=_named(jax.tree_util.tree_map(lambda s: s, pspecs,
                                             is_leaf=lambda x: isinstance(x, P)), mesh),
        ),
    )


def _lower_compile(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Tuple[Any, Any, str, Any]:
    """Lower + compile one step function; returns (lowered, compiled, kind, params)."""
    fsdp = cfg.param_count() >= FSDP_THRESHOLD
    kind, specs = input_specs(cfg, shape)
    params_struct = param_specs_struct(cfg)
    with shd.use_mesh(mesh):
        if kind == "train":
            opt_cfg = opt_config_for(cfg)
            state_struct = jax.eval_shape(
                lambda: step_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg))
            st_sh = state_shardings(state_struct.params, state_struct.opt, mesh, fsdp)
            b_sh = _named(shd.batch_specs_tree(specs["batch"], mesh, shape.global_batch), mesh)
            fn = functools.partial(step_lib.train_step, cfg=cfg, opt_cfg=opt_cfg)
            jfn = jax.jit(fn, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
            lowered = jfn.lower(state_struct, specs["batch"])
        elif kind == "prefill":
            p_sh = _named(shd.param_specs(params_struct, mesh, fsdp=fsdp), mesh)
            b_sh = _named(shd.batch_specs_tree(specs["batch"], mesh, shape.global_batch), mesh)
            fn = functools.partial(step_lib.prefill_step, cfg=cfg, cache_len=shape.seq_len)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jfn.lower(params_struct, specs["batch"])
        else:  # decode
            p_sh = _named(shd.param_specs(params_struct, mesh, fsdp=fsdp), mesh)
            tok_sh = _named(shd.batch_specs_tree(specs["token"], mesh, shape.global_batch), mesh)
            pos_sh = _named(shd.batch_specs_tree(specs["positions"], mesh, shape.global_batch), mesh)
            c_sh = _named(shd.cache_specs_tree(specs["cache"], mesh, shape.global_batch), mesh)
            fn = functools.partial(step_lib.decode_step, cfg=cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, tok_sh, pos_sh, c_sh), donate_argnums=(3,))
            lowered = jfn.lower(params_struct, specs["token"], specs["positions"], specs["cache"])
        compiled = lowered.compile()
    return lowered, compiled, kind, params_struct


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=n_layers, scan_layers=False,
        num_encoder_layers=min(cfg.num_encoder_layers, 2))


def probe_costs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Tuple[float, float, float]:
    """Exact per-device (flops, bytes, collective bytes), extrapolated
    linearly in depth from two small fully-unrolled probes (L=p and L=2p).
    Scanned production lowerings under-count while-body costs on the CPU
    backend; the probes make every op's cost visible exactly once."""
    p = len(cfg.pattern_period())
    p = max(p, 1)
    _, c1, _, _ = _lower_compile(_probe_cfg(cfg, p), shape, mesh)
    costs_p = roofline.costs_of(c1)
    del c1
    _, c2, _, _ = _lower_compile(_probe_cfg(cfg, 2 * p), shape, mesh)
    costs_2p = roofline.costs_of(c2)
    del c2
    return roofline.probe_extrapolate(costs_p, costs_2p, p, cfg.num_layers)


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    mesh=None,
    cfg_override: Optional[ModelConfig] = None,
    probe: bool = True,
) -> Tuple[Any, Any, Dict[str, Any]]:
    """Lower + compile one cell; returns (lowered, compiled, record)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"skipped cell: {why}")
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    t0 = time.time()
    lowered, compiled, kind, params_struct = _lower_compile(cfg, shape, mesh)
    t_compile = time.time() - t0

    if probe:
        flops, byts, coll = probe_costs(cfg, shape, mesh)
    else:
        flops, byts, coll = roofline.costs_of(compiled)

    report = roofline.analyze(
        arch=arch, shape_name=shape_name,
        mesh_name="2x16x16" if multi_pod else "16x16", chips=chips,
        cfg=cfg, shape=shape, params_tree=params_struct,
        flops=flops, byts=byts, coll=coll, compiled=compiled)

    ma = compiled.memory_analysis()
    record = {
        **report.as_dict(),
        "kind": kind,
        "fsdp": cfg.param_count() >= FSDP_THRESHOLD,
        "compile_s": round(t_compile, 2),
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
    }
    return lowered, compiled, record


def run_cells(cells, multipods, out_dir: Optional[str], probe: bool = True):
    results = []
    meshes = {mp: make_production_mesh(multi_pod=mp) for mp in multipods}
    for arch, shape_name in cells:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, why = shape_applicable(cfg, shape)
        for mp in multipods:
            mesh_name = "2x16x16" if mp else "16x16"
            tag = f"{arch}__{shape_name}__{mesh_name}"
            if not ok:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "SKIP", "reason": why}
                print(f"[SKIP] {tag}: {why}")
            else:
                try:
                    _, compiled, rec = lower_cell(arch, shape_name, mp, mesh=meshes[mp],
                                                  probe=probe)
                    rec["status"] = "OK"
                    hbm = (rec["argument_bytes_per_device"] + rec["temp_bytes_per_device"]) / 1e9
                    print(f"[OK]   {tag}: compile={rec['compile_s']}s "
                          f"mem/dev={hbm:.2f}GB bottleneck={rec['bottleneck']} "
                          f"useful={rec['useful_ratio']:.2f}")
                    del compiled
                except Exception as e:  # noqa
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
            results.append(rec)
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true", help="only the 512-chip mesh")
    ap.add_argument("--multipod-too", action="store_true", help="both meshes")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the flop-accounting probes (multi-pod proof runs)")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    multipods = [True] if args.multipod else ([False, True] if args.multipod_too else [False])
    results = run_cells(cells, multipods, args.out, probe=not args.no_probe)
    n_ok = sum(r.get("status") == "OK" for r in results)
    n_skip = sum(r.get("status") == "SKIP" for r in results)
    n_fail = sum(r.get("status") == "FAIL" for r in results)
    print(f"\n=== dry-run: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL ===")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
