"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms, all in seconds, per (arch × shape × mesh):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = collective_bytes_per_device / ICI_link_bandwidth

``cost_analysis()`` on the compiled executable reports per-device (post-
SPMD-partitioning) flops/bytes. Collective bytes are not in cost_analysis:
we parse the post-optimization HLO (``compiled.as_text()``) and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (while-looped collectives are multiplied
by the trip count when XLA exposes it via the loop bound; scanned-layer
loops dominate and their trip count equals the layer count, which we take
from the arch config).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Tuple

from ..units import BYTES_PER_GB

# -- TPU v5e hardware constants (per assignment) ------------------------------
PEAK_FLOPS = 197e12     # bf16 per chip  # lint: unit(FLOP/s)
HBM_BW = 819e9          # per chip  # lint: unit(B/s)
ICI_BW = 50e9           # per link  # lint: unit(B/s)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (possibly a tuple)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, loop_trip_counts: bool = True) -> Tuple[int, Dict[str, int]]:
    """Sum result bytes of collective ops in post-optimization HLO.

    Ops inside while-loop bodies are counted once per iteration when the
    loop publishes a trip count; XLA CPU does not annotate that, so we use
    the conservative convention: count each op once, then the caller scales
    ops inside the scanned-layer loop by the layer count (see
    ``scale_scanned``).
    """
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}: ]+?)\s+([\w\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        # normalize: all-gather-start, all-reduce-done, etc.
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-start"):
                base = k
                break
        if base is None:
            continue
        b = _shape_bytes(m.group(1))
        per_kind[base] += b
        counts[base] += 1
    return sum(per_kind.values()), {"bytes": per_kind, "counts": counts}


def while_scaled_collective_bytes(hlo_text: str, layer_trip: int) -> Tuple[int, Dict[str, Any]]:
    """Collective bytes with while-body ops scaled by ``layer_trip``.

    The post-opt HLO contains one computation per while body; ops there
    execute ``trip_count`` times. We detect body computations by the
    ``%body``/``while`` naming convention XLA uses and scale their
    contribution.
    """
    total = 0
    detail: Dict[str, Any] = {"top": {}, "body_scaled": {}}
    # split into computations
    chunks = re.split(r"\n(?=%?\w[\w.\-]*\s*(?:\([^)]*\))?\s*->|\w+\s*\{)", hlo_text)
    body_re = re.compile(r"(body|while)", re.IGNORECASE)
    for chunk in chunks:
        header = chunk.splitlines()[0] if chunk.splitlines() else ""
        b, d = collective_bytes(chunk)
        if body_re.search(header):
            total += b * layer_trip
            for k, v in d["bytes"].items():
                detail["body_scaled"][k] = detail["body_scaled"].get(k, 0) + v * layer_trip
        else:
            total += b
            for k, v in d["bytes"].items():
                detail["top"][k] = detail["top"].get(k, 0) + v
    return total, detail


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float          # upper bound: per-op HLO bytes (unfused CPU HLO)
    memory_lb_s: float       # lower bound: each live byte touched once
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    memory_per_device_gb: float
    note: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def model_flops(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference forward."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active_params * tokens


def active_param_count(params_tree) -> Tuple[int, int]:
    """(total, active) param counts; routed experts discounted by k/E."""
    import jax
    import math as _m

    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        n = _m.prod(leaf.shape)
        total += n
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x))) for x in path)
        if "experts" in p:
            expert += n
    return total, expert


def costs_of(compiled) -> Tuple[float, float, float]:
    """(flops, bytes, collective_bytes) per device from one compiled exe.

    Collectives inside while bodies are counted once — callers using scanned
    layers must extrapolate via probes (see ``probe_extrapolate``).
    """
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll, _ = collective_bytes(compiled.as_text())
    return flops, byts, float(coll)


def probe_extrapolate(costs_p: Tuple[float, float, float],
                      costs_2p: Tuple[float, float, float],
                      period: int, num_layers: int) -> Tuple[float, float, float]:
    """Linear-in-depth extrapolation: cost(L) = a + b·L from two probes at
    L=period and L=2·period (both fully unrolled so per-op accounting is
    exact)."""
    out = []
    for c1, c2 in zip(costs_p, costs_2p):
        b = (c2 - c1) / period
        a = c1 - b * period
        out.append(max(a + b * num_layers, 0.0))
    return tuple(out)


def analyze(
    *,
    arch: str,
    shape_name: str,
    mesh_name: str,
    chips: int,
    cfg,
    shape,
    params_tree,
    flops: float,
    byts: float,
    coll: float,
    compiled=None,
) -> RooflineReport:
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll / ICI_BW
    mem_lb = float("nan")
    if compiled is not None:
        try:
            ma = compiled.memory_analysis()
            mem_lb = (ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                      ma.output_size_in_bytes) / HBM_BW
        except Exception:
            pass
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    total_p, expert_p = active_param_count(params_tree)
    if cfg.num_experts:
        active = total_p - expert_p * (1.0 - cfg.num_experts_per_tok / cfg.num_experts)
    else:
        active = total_p
    mf = model_flops(cfg, shape, int(active))
    useful = mf / (flops * chips) if flops else 0.0

    mem_gb = float("nan")
    if compiled is not None:
        try:
            ma = compiled.memory_analysis()
            mem_gb = (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / BYTES_PER_GB
        except Exception:
            pass

    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=float(coll),
        compute_s=compute_s, memory_s=memory_s, memory_lb_s=mem_lb,
        collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf, useful_ratio=float(useful),
        memory_per_device_gb=float(mem_gb),
    )
