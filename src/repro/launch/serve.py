"""Serving driver: the fleet the GT-DRL control plane schedules.

``ModelServer`` runs prefill + batched decode for one architecture (one
"task type" in the paper's terms). ``Fleet`` stands up one server per task
type per data center and exposes the throughput/power surface the paper's
CWM needs (execution rates ER_{i,d} are tokens/s here — derived from the
roofline for the TPU node type, measured for the CPU host).

CPU-runnable: smoke configs, small batches (see examples/serve_fleet.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..configs.base import ModelConfig
from ..models import model as model_lib
from ..train.step import decode_step, prefill_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: jnp.ndarray  # (S,) int32
    max_new: int = 16


class ModelServer:
    """Single-arch server: continuous batching over a fixed decode batch."""

    def __init__(self, cfg: ModelConfig, *, batch_size: int = 8,
                 cache_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.cache_len = cache_len
        self.params = model_lib.init(jax.random.PRNGKey(seed), cfg)
        self._prefill = jax.jit(functools.partial(
            prefill_step, cfg=cfg, cache_len=cache_len))
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg),
                               donate_argnums=(3,))
        self.stats = {"requests": 0, "tokens": 0, "decode_s": 0.0, "prefill_s": 0.0}

    def _batchify(self, reqs: List[Request]) -> Dict[str, jnp.ndarray]:
        maxlen = max(int(r.prompt.shape[0]) for r in reqs)
        toks = jnp.stack([
            jnp.pad(r.prompt, (0, maxlen - r.prompt.shape[0])) for r in reqs])
        batch = {"tokens": toks.astype(jnp.int32)}
        if self.cfg.rope_mode == "mrope":
            b, s = toks.shape
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3))
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (len(reqs), self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        return batch

    def generate(self, reqs: List[Request], greedy: bool = True) -> Dict[int, List[int]]:
        """Prefill all prompts, then decode max_new tokens, batched."""
        assert len(reqs) <= self.batch_size
        batch = self._batchify(reqs)
        b, s = batch["tokens"].shape
        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        self.stats["prefill_s"] += time.time() - t0
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs: Dict[int, List[int]] = {r.uid: [] for r in reqs}
        max_new = max(r.max_new for r in reqs)
        t0 = time.time()
        for step in range(max_new):
            # the token produced by the previous pass (prefill for step 0)
            # IS generation `step`; decode then advances the cache past it
            for i, r in enumerate(reqs):
                if step < r.max_new:
                    outs[r.uid].append(int(token[i, 0]))
            if step == max_new - 1:
                break
            pos = jnp.full((b, 1), s + step, jnp.int32)
            if self.cfg.rope_mode == "mrope":
                pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
            logits, cache = self._decode(self.params, token, pos, cache)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, :1]
        self.stats["decode_s"] += time.time() - t0
        self.stats["requests"] += len(reqs)
        self.stats["tokens"] += b * max_new
        return outs

    def tokens_per_second(self) -> float:
        t = self.stats["decode_s"]
        return self.stats["tokens"] / t if t > 0 else 0.0


class Fleet:
    """The serving fleet behind the paper's CWM: task types × data centers.

    ``route(assignments)`` takes the GT-DRL arrival-rate matrix AR[i, d]
    (requests/hour) and dispatches batches accordingly — the actual data
    plane the control plane's decisions act on.
    """

    def __init__(self, archs: List[str], num_dcs: int, *, smoke: bool = True,
                 batch_size: int = 4, cache_len: int = 128):
        self.archs = archs
        self.num_dcs = num_dcs
        self.servers: Dict[Tuple[int, int], ModelServer] = {}
        for i, a in enumerate(archs):
            cfg = get_config(a)
            cfg = cfg.smoke() if smoke else cfg
            for d in range(num_dcs):
                self.servers[(i, d)] = ModelServer(
                    cfg, batch_size=batch_size, cache_len=cache_len, seed=i * 97 + d)

    def route(self, ar: jnp.ndarray, requests_per_unit: int = 1,
              prompt_len: int = 16, max_new: int = 4) -> Dict[str, Any]:
        """Dispatch a scaled-down sample of the assignment matrix."""
        ar = jnp.asarray(ar)
        share = ar / jnp.maximum(jnp.sum(ar), 1e-9)
        uid = 0
        dispatched = {}
        for i in range(len(self.archs)):
            for d in range(self.num_dcs):
                n = int(round(float(share[i, d]) * requests_per_unit * len(self.archs) * self.num_dcs))
                n = min(n, self.servers[(i, d)].batch_size)
                if n <= 0:
                    continue
                reqs = [Request(uid + k, jnp.ones((prompt_len,), jnp.int32), max_new)
                        for k in range(n)]
                uid += n
                self.servers[(i, d)].generate(reqs)
                dispatched[(i, d)] = n
        return {"dispatched": dispatched,
                "total": sum(dispatched.values()),
                "per_server_tps": {k: s.tokens_per_second()
                                   for k, s in self.servers.items() if s.stats["tokens"]}}
