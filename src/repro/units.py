"""Named unit-conversion constants — the only sanctioned scale factors.

The simulator core mixes $/kWh prices with W of IT power, GB payloads with
token counts, and ms latencies with tasks/h rates; PR 3 fixed three real
bugs that were nothing but a scale factor applied (or dropped) in the wrong
place. Every cross-unit conversion therefore goes through a constant below,
each declared with its unit via ``# lint: unit(...)`` so
``repro.lint.units`` can treat it as a *dimensioned* quantity: ``dp /
W_PER_KW`` converts W → kW in the dimensional analysis, while a bare
``dp / 1000.0`` is flagged as an undeclared magic scale factor.

The values are bit-identical to the literals they replaced (pure renames;
``2.0 ** 30`` folds to exactly 1073741824.0), so every engine output is
unchanged — pinned by the parity tests in ``tests/test_units.py``.
"""
from __future__ import annotations

W_PER_KW = 1000.0            # lint: unit(W/kW)
MS_PER_H = 3.6e6             # lint: unit(ms/h)
S_PER_H = 3600.0             # lint: unit(s/h)
BYTES_PER_GB = 1e9           # lint: unit(B/GB)
BYTES_PER_GIB = 2.0 ** 30    # lint: unit(B/GiB)
BYTES_PER_FP32_TOKEN = 4.0   # lint: unit(B/token)
