"""Fault-tolerant checkpointing: atomic, resumable, mesh-shape-agnostic.

Design (scaled-down tensorstore pattern, no external deps):

  * one directory per step: ``ckpt_dir/step_000123/``;
  * each top-level state field is an ``.npz`` of flattened leaves keyed by
    pytree path, written to ``<name>.npz.tmp`` then atomically renamed;
  * a ``MANIFEST.json`` (with per-file sha256) is written *last* — a
    checkpoint without a manifest is treated as torn and ignored by
    ``latest_step`` (crash-consistent restore);
  * arrays are saved device-agnostic (gathered to host), so a checkpoint
    written on a 256-chip mesh restores onto 512 chips or 1 CPU — restore
    device-puts against the *current* mesh's shardings (elastic rescale);
  * ``keep`` old checkpoints are retained for rollback after bad nodes.

On a real multi-host pod, each host would write only its addressable
shards; the manifest/atomic-rename protocol is unchanged.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten_named(tree: Any) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        out[_path_str(path)] = arr
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write --------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], extra: Optional[dict] = None) -> str:
        """Atomically write a checkpoint for ``step``.

        ``state`` maps field name -> pytree (params, opt, data-state, ...).
        """
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "files": {}, "extra": extra or {}}
        for name, tree in state.items():
            named = _flatten_named(tree)
            fpath = os.path.join(tmp, f"{name}.npz")
            np.savez(fpath, **named)
            manifest["files"][name] = {"sha256": _sha256(fpath)}
        # manifest is last: its presence marks the checkpoint complete
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # -- read ---------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(
        self,
        step: int,
        templates: Dict[str, Any],
        shardings: Optional[Dict[str, Any]] = None,
        verify: bool = True,
    ) -> Tuple[Dict[str, Any], dict]:
        """Restore ``templates``-shaped pytrees; optionally shard onto the
        current mesh (``shardings`` maps field -> pytree of NamedSharding)."""
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        out = {}
        for name, template in templates.items():
            fpath = os.path.join(d, f"{name}.npz")
            if verify and manifest["files"][name]["sha256"] != _sha256(fpath):
                raise IOError(f"checkpoint {d} field {name}: sha256 mismatch (corrupt)")
            data = np.load(fpath)
            paths = jax.tree_util.tree_flatten_with_path(template)[0]
            treedef = jax.tree_util.tree_structure(template)
            leaves = []
            for path, leaf in paths:
                arr = data[_path_str(path)]
                want = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
                leaves.append(arr.astype(want, copy=False))
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
            if shardings and name in shardings:
                tree = jax.tree_util.tree_map(
                    lambda a, s: jax.device_put(a, s), tree, shardings[name]
                )
            else:
                tree = jax.tree_util.tree_map(jnp.asarray, tree)
            out[name] = tree
        return out, manifest.get("extra", {})

    def restore_latest(self, templates, shardings=None, verify=True):
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, templates, shardings, verify)
        return step, state, extra

    # -- retention ------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
