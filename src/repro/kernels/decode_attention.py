"""Pallas TPU flash-decode: single-token attention over a long KV cache.

TPU-native design:
  * grid is (batch, kv_heads, kv_blocks); each program loads one
    ``block_k × head_dim`` KV tile into VMEM and scores it against the whole
    GQA *query group* at once (``group × head_dim`` tile), so MQA/GQA decode
    amortizes the KV stream over all query heads that share it — this is the
    decode-side bandwidth optimization the roofline demands (decode is HBM
    bound; KV bytes dominate);
  * the kv dimension is sequential ("arbitrary") and carries the online
    softmax state in VMEM scratch, exactly like the prefill kernel;
  * ragged cache lengths are masked from a lane-replicated lengths operand.

For multi-megabyte caches a real deployment would add a second split-KV grid
axis plus a cross-block reduction; block-sequential streaming is already
bandwidth-optimal on TPU because the kv grid dimension is executed as a
hardware loop with double-buffered VMEM copies.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
LANES = 128


def _decode_kernel(
    q_ref,       # (1, 1, group, d)
    k_ref,       # (1, 1, block_k, d)
    v_ref,       # (1, 1, block_k, d)
    len_ref,     # (1, LANES) int32, lane-replicated valid length
    o_ref,       # (1, 1, group, d)
    m_scr, l_scr, acc_scr,
    *,
    sm_scale: float,
    softcap: float,
    block_k: int,
):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0, 0]
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    @pl.when(ki * block_k < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (group, block_k)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        # zero padded rows: a partial tail block reads out-of-bounds garbage
        # and 0-weight × garbage would still poison the PV matmul
        v = jnp.where(k_pos.reshape(-1, 1) < length, v, 0.0)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "softcap", "block_k", "interpret"),
)
def decode_attention(
    q: jnp.ndarray,        # (B, H, D) one new token per sequence
    k_cache: jnp.ndarray,  # (B, S, KVH, D)
    v_cache: jnp.ndarray,  # (B, S, KVH, D)
    lengths: jnp.ndarray,  # (B,) int32 valid positions per sequence
    *,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, d = q.shape
    s_len, kvh = k_cache.shape[1], k_cache.shape[2]
    assert h % kvh == 0
    group = h // kvh
    scale = float(sm_scale) if sm_scale is not None else d ** -0.5
    block_k = min(block_k, s_len)
    nk = pl.cdiv(s_len, block_k)

    qt = q.reshape(b, kvh, group, d)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, KVH, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    len_rep = jnp.broadcast_to(lengths.astype(jnp.int32)[:, None], (b, LANES))

    kernel = functools.partial(
        _decode_kernel, sm_scale=scale, softcap=softcap, block_k=block_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, LANES), lambda b_, h_, ki: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qt, kt, vt, len_rep)
    return out.reshape(b, h, d)
