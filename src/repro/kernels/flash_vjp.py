"""Flash-attention forward/backward with O(S·chunk) memory, pure jnp.

``jax.lax.scan`` reverse-mode saves every carry — for an online-softmax
accumulator that means nc × |output| residuals per layer (≈70 GB/layer at
4k×32 heads), which is exactly the problem flash attention's backward
solves. This module implements the canonical flash backward (save only
(out, lse); re-stream KV chunks, rebuild p from lse, accumulate dq/dk/dv)
as a ``custom_vjp``, so the CPU-lowered dry-run shows the same memory
behavior the Pallas kernel pair has on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _bcast_kv(k: jnp.ndarray, h: int) -> jnp.ndarray:
    kvh = k.shape[2]
    if kvh == h:
        return k
    return jnp.repeat(k, h // kvh, axis=2)


def _mask(sq, skv, chunk, ci, q_offset, causal, window):
    q_pos = q_offset + jnp.arange(sq)
    k_pos = ci * chunk + jnp.arange(chunk)
    m = (k_pos < skv)[None, :]
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m  # (sq, chunk)


def _fwd_stream(q, kb, vb, *, scale, softcap, causal, window, q_offset, chunk, skv):
    """Returns (out (b,sq,h,d), lse (b,h,sq))."""
    b, sq, h, d = q.shape
    nc = kb.shape[1] // chunk
    kc = kb.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = vb.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ci, kck, vck = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kck.astype(jnp.float32)) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        msk = _mask(sq, skv, chunk, ci, q_offset, causal, window)
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vck.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (jnp.arange(nc), kc, vc))
    lsafe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / lsafe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(lsafe)
    return out, lse


@functools.lru_cache(maxsize=None)
def _make(causal: bool, window: int, softcap: float, q_offset: int, chunk: int,
          h: int, kvh: int, skv: int):
    group = h // kvh

    @jax.custom_vjp
    def attn(q, k, v, scale):
        kb, vb, _ = _padded(k, v)
        out, _ = _fwd_stream(q, kb, vb, scale=scale, softcap=softcap, causal=causal,
                             window=window, q_offset=q_offset, chunk=chunk, skv=skv)
        return out

    def _padded(k, v):
        kb = _bcast_kv(k, h)
        vb = _bcast_kv(v, h)
        pad = (-skv) % chunk
        if pad:
            zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kb, vb = zp(kb), zp(vb)
        return kb, vb, pad

    def fwd(q, k, v, scale):
        kb, vb, _ = _padded(k, v)
        out, lse = _fwd_stream(q, kb, vb, scale=scale, softcap=softcap, causal=causal,
                               window=window, q_offset=q_offset, chunk=chunk, skv=skv)
        return out, (q, k, v, scale, out, lse)

    def bwd(res, g):
        q, k, v, scale, out, lse = res
        b, sq, _, d = q.shape
        kb, vb, pad = _padded(k, v)
        nc = kb.shape[1] // chunk
        kc = kb.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
        vc = vb.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
        qf = q.astype(jnp.float32)
        go = g.astype(jnp.float32).transpose(0, 2, 1, 3)      # (b,h,sq,d)
        of = out.astype(jnp.float32).transpose(0, 2, 1, 3)
        delta = jnp.sum(go * of, axis=-1)                     # (b,h,sq)

        def body(dq, inp):
            ci, kck, vck = inp
            kf, vf = kck.astype(jnp.float32), vck.astype(jnp.float32)
            s1 = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
            if softcap > 0.0:
                t = jnp.tanh(s1 / softcap)
                s = t * softcap
            else:
                s = s1
            msk = _mask(sq, skv, chunk, ci, q_offset, causal, window)
            s = jnp.where(msk[None, None], s, NEG_INF)
            p = jnp.exp(s - lse[..., None])                   # (b,h,q,k)
            dv_c = jnp.einsum("bhqk,bhqd->bkhd", p, go)
            dp = jnp.einsum("bhqd,bkhd->bhqk", go, vf)
            ds = p * (dp - delta[..., None])                  # d/d(s_soft)
            if softcap > 0.0:
                ds = ds * (1.0 - t * t)
            ds = jnp.where(msk[None, None], ds, 0.0)
            dq_c = jnp.einsum("bhqk,bkhd->bqhd", ds, kf) * scale
            dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qf) * scale
            return dq + dq_c, (dk_c, dv_c)

        dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
        dq, (dk_chunks, dv_chunks) = jax.lax.scan(body, dq0, (jnp.arange(nc), kc, vc))
        dk = dk_chunks.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, d)[:, :skv]
        dv = dv_chunks.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, d)[:, :skv]
        if group > 1:  # GQA: fold query-head groups back onto kv heads
            dk = dk.reshape(b, skv, kvh, group, d).sum(axis=3)
            dv = dv.reshape(b, skv, kvh, group, d).sum(axis=3)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None

    attn.defvjp(fwd, bwd)
    return attn


def flash_attention_jnp(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    q_offset: int = 0,
    chunk: int = 512,
) -> jnp.ndarray:
    """Differentiable flash-equivalent attention (O(S·chunk) fwd AND bwd)."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    chunk = min(chunk, skv)
    scale = jnp.float32(sm_scale if sm_scale is not None else d ** -0.5)
    fn = _make(bool(causal), int(window), float(softcap), int(q_offset),
               int(chunk), int(h), int(kvh), int(skv))
    return fn(q, k, v, scale)
