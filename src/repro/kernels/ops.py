"""Jit'd public entry points for the kernels with backend dispatch.

``impl`` semantics:
  * ``auto``   — Pallas kernel on TPU; jnp reference elsewhere (the CPU
                 container, dry-run lowering, unit tests). FLOP/byte
                 accounting is identical either way.
  * ``ref``    — always the pure-jnp oracle.
  * ``pallas`` — force the kernel (real TPU).
  * ``interpret`` — kernel body emulated on CPU (used by the kernel tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    q_offset: int = 0,
    impl: str = "auto",
    block_q: int = 512,
    block_k: int = 512,
) -> jnp.ndarray:
    """Batched multi-head (GQA) attention, (B, S, H, D) layout."""
    if impl == "auto":
        # CPU (tests + dry-run lowering): the chunked streaming form, whose
        # memory/byte profile matches the Pallas kernel's VMEM streaming.
        impl = "pallas" if _on_tpu() else "chunked"
    if impl == "chunked":
        from .flash_vjp import flash_attention_jnp

        return flash_attention_jnp(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            softcap=softcap, q_offset=q_offset,
        )
    if impl == "ref":
        return _ref.attention_ref(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            softcap=softcap, q_offset=q_offset,
        )
    return _flash_pallas(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        softcap=softcap, q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    impl: str = "auto",
    block_k: int = 512,
) -> jnp.ndarray:
    """Single-token decode attention over a KV cache, (B, H, D) query."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        # Distributed layout (GSPMD): the cache stays *sequence*-sharded over
        # "model" and the query is replicated across it — each model shard
        # scores its own KV span and the softmax/PV reductions psum across
        # shards (the multi-chip analogue of split-KV flash-decode). Without
        # these constraints GSPMD reshards the whole cache to head-sharded
        # every step — measured as the dominant collective of all decode
        # cells.
        from ..distributed.sharding import constrain

        q = constrain(q, ("pod", "data"), None, None)
        k_cache = constrain(k_cache, ("pod", "data"), "model", None, None)
        v_cache = constrain(v_cache, ("pod", "data"), "model", None, None)
        out = _ref.decode_attention_ref(
            q, k_cache, v_cache, lengths, sm_scale=sm_scale, softcap=softcap
        )
        return constrain(out, ("pod", "data"), None, None)
    return _decode_pallas(
        q, k_cache, v_cache, lengths, sm_scale=sm_scale, softcap=softcap,
        block_k=block_k, interpret=(impl == "interpret"),
    )
