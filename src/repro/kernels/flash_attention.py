"""Pallas TPU flash attention (prefill) with explicit BlockSpec VMEM tiling.

TPU-native design notes (vs a CUDA flash port):
  * tiles are MXU-aligned: ``block_q`` × ``head_dim`` and ``block_k`` ×
    ``head_dim`` with 128-multiples preferred so the systolic array is full;
  * the grid is (batch, q_heads, q_blocks, kv_blocks) with the kv dimension
    declared "arbitrary" (sequential) so the online-softmax accumulator in
    VMEM scratch carries across kv steps — this is the TPU analogue of a
    persistent CTA loop;
  * GQA is handled in the BlockSpec index maps (each q head reads kv head
    ``h // group``) so no repeated KV is materialized in HBM;
  * running max / sum live in VMEM scratch replicated across the 128-lane
    minor dimension, which is the layout the VPU wants.

Softmax statistics are fp32 regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    causal: bool,
    window: int,
    sm_scale: float,
    softcap: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_kv: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Causal / window block-level skip: only run the MXU work when this
    # (q_block, kv_block) tile intersects the mask support.
    block_needed = True
    if causal:
        first_q = q_offset + qi * block_q
        first_k = ki * block_k
        block_needed = jnp.logical_and(
            first_k <= first_q + block_q - 1,
            True if window <= 0 else (first_k + block_k - 1 > first_q - window),
        )

    @pl.when(block_needed if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (block_q, block_k)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = (k_pos < seq_kv)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (block_q, 1), lane-replicated storage
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        l_cur = jnp.sum(p, axis=-1, keepdims=True)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + l_cur
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        # zero padded rows of a partial tail block (see decode kernel note)
        v = jnp.where(k_pos[:1].reshape(-1, 1) < seq_kv, v, 0.0)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "sm_scale", "softcap", "block_q", "block_k",
        "q_offset", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KVH, D)
    v: jnp.ndarray,  # (B, Skv, KVH, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    scale = float(sm_scale) if sm_scale is not None else d ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)

    # (B, H, S, D) layout inside the kernel: the head dim becomes a pure grid
    # dimension and each tile is a clean (block, d) VMEM rectangle.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        sm_scale=scale,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        seq_q=sq,
        seq_kv=skv,
        q_offset=q_offset,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
