"""Pallas TPU kernels: flash attention (prefill) + flash-decode."""
