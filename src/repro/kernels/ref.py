"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernels are validated against these in
interpret mode over shape/dtype sweeps, and the CPU dry-run path lowers these
(XLA fuses them; FLOP/byte accounting is identical by construction).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _broadcast_kv(k: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """(B, S, KVH, D) -> (B, S, H, D) by repeating each kv head group-size times."""
    b, s, kvh, d = k.shape
    group = num_q_heads // kvh
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KVH, D)
    v: jnp.ndarray,  # (B, Skv, KVH, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Multi-head attention with GQA, optional causal / sliding-window mask.

    ``q_offset`` is the absolute position of q[0] (used at decode time when
    Sq < Skv and the causal frontier sits at q_offset + i).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kb = _broadcast_kv(k, h)
    vb = _broadcast_kv(v, h)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kb.astype(jnp.float32))
    s = s * sm_scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vb.astype(jnp.float32))
    return o.astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KVH, D)
    v: jnp.ndarray,  # (B, Skv, KVH, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
    q_offset: int = 0,
    chunk: int = 512,
) -> jnp.ndarray:
    """Flash-equivalent streaming attention in pure jnp (scan over KV chunks
    with an online softmax). Semantically identical to :func:`attention_ref`
    but with O(Sq·chunk) live memory instead of O(Sq·Skv) — this is what the
    dry-run lowers on CPU so memory/byte accounting matches the Pallas
    kernel's behavior on TPU.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if skv <= chunk:
        return attention_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale, softcap=softcap, q_offset=q_offset)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    kb = _broadcast_kv(k, h)
    vb = _broadcast_kv(v, h)
    pad = (-skv) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kb, vb = zp(kb), zp(vb)
    nc = (skv + pad) // chunk
    kc = kb.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = vb.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ci, kck, vck = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kck.astype(jnp.float32)) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = (k_pos < skv)[None, :]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vck.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (jnp.arange(nc), kc, vc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,  # (B, H, D) single new token per sequence
    k_cache: jnp.ndarray,  # (B, S, KVH, D)
    v_cache: jnp.ndarray,  # (B, S, KVH, D)
    lengths: jnp.ndarray,  # (B,) int32: number of valid cache positions
    *,
    sm_scale: Optional[float] = None,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Single-token decode attention over a (ragged-length) KV cache."""
    b, h, d = q.shape
    s_len = k_cache.shape[1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kb = _broadcast_kv(k_cache, h)  # (B, S, H, D)
    vb = _broadcast_kv(v_cache, h)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kb.astype(jnp.float32))
    s = s * sm_scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(s_len)[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, vb.astype(jnp.float32))
    return o.astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
