"""recurrentgemma-9b [hybrid]: RG-LRU + local attention 1:2 (arXiv:2402.19427).

Period-3 pattern (recur, recur, attn); local attention window 2048, MQA
(kv=1, head_dim 256); GeGLU MLP; embeddings scaled by sqrt(d). The RG-LRU
state is O(1) and the attention KV cache is window-bounded -> long_500k runs.
"""
from .base import ATTN, RECUR, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    attn_window=2048,
    act="geglu",
    block_pattern=(RECUR, RECUR, ATTN),
    lru_width=4096,
    tie_embeddings=True,
    scan_layers=False,
)
