"""Architecture registry + ShapeDtypeStruct input specs for the dry-run.

``input_specs(cfg, shape)`` returns (step_kind, kwargs-of-ShapeDtypeStruct)
for the step function the cell lowers: ``train_step`` / ``prefill_step`` for
train/prefill kinds, ``decode_step`` (one token + full cache pytree specs)
for decode kinds. Nothing here allocates device memory — cache/param shapes
come from ``jax.eval_shape``.
"""
from __future__ import annotations

import importlib
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .base import ATTN, MLSTM, RECUR, SLSTM, SHAPES, ModelConfig, ShapeConfig, shape_applicable

_ARCH_MODULES = {
    "whisper-base": "whisper_base",
    "qwen2-7b": "qwen2_7b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3.2-1b": "llama3_2_1b",
    "llama3.2-3b": "llama3_2_3b",
    "xlstm-125m": "xlstm_125m",
    "arctic-480b": "arctic_480b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def _f(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def _i(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


VISION_TOKENS = 1024  # stub patch-embedding span for the vlm family


def batch_specs(cfg: ModelConfig, b: int, s: int, with_labels: bool) -> Dict[str, Any]:
    """Model-input specs for a full-sequence (train / prefill) pass."""
    specs: Dict[str, Any] = {"tokens": _i((b, s))}
    if with_labels:
        specs["labels"] = _i((b, s))
    if cfg.is_encoder_decoder:
        specs["frames"] = _f((b, cfg.encoder_seq, cfg.d_model))
    if cfg.rope_mode == "mrope":
        specs["positions"] = _i((b, s, 3))
    if cfg.frontend == "vision_stub":
        specs["vision_embeds"] = _f((b, min(VISION_TOKENS, s), cfg.d_model))
    return specs


def cache_specs(cfg: ModelConfig, b: int, cache_len: int):
    """Decode-cache specs without allocating (eval_shape)."""
    from ..models import model as model_lib
    from ..models import transformer

    if cfg.is_encoder_decoder:
        def make():
            params = model_lib.init(jax.random.PRNGKey(0), cfg)
            enc_out = jnp.zeros((b, cfg.encoder_seq, cfg.d_model),
                                {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype])
            return model_lib.init_cache(params, cfg, b, cache_len, enc_out=enc_out)

        return jax.eval_shape(make)
    return jax.eval_shape(lambda: transformer.init_cache(cfg, b, cache_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[str, Dict[str, Any]]:
    """(step_kind, specs) for one (arch × shape) cell."""
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape.name} skipped: {why}")
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return "train", {"batch": batch_specs(cfg, b, s, with_labels=True)}
    if shape.kind == "prefill":
        return "prefill", {"batch": batch_specs(cfg, b, s, with_labels=False)}
    # decode: one token against a cache of seq_len positions
    specs: Dict[str, Any] = {
        "token": _i((b, 1)),
        "positions": _i((b, 1, 3)) if cfg.rope_mode == "mrope" else _i((b, 1)),
        "cache": cache_specs(cfg, b, s),
    }
    return "decode", specs


def param_specs_struct(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    from ..models import model as model_lib

    return jax.eval_shape(lambda: model_lib.init(jax.random.PRNGKey(0), cfg))


def all_cells():
    """Every (arch, shape) cell with its applicability."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for sname, shp in SHAPES.items():
            ok, why = shape_applicable(cfg, shp)
            out.append((a, sname, ok, why))
    return out
