"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed top-4 (hf:Qwen/Qwen1.5-MoE-A2.7B)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    shared_d_ff=1408,
    router_aux_loss=0.001,
    tie_embeddings=False,
    moe_impl="ep",
    act_shard="seq",
)
