"""qwen2-vl-72b [vlm]: M-RoPE backbone (arXiv:2409.12191).

Vision frontend is a stub: input_specs() provides (B, 1024, d) patch
embeddings overwriting the first 1024 token positions; M-RoPE position ids
come in as (B, S, 3) = (temporal, height, width) streams.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
    tie_embeddings=False,
    act_shard="seq",
)
