"""arctic-480b [moe]: 128 experts top-2 + dense residual (Snowflake Arctic)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    num_experts=128,
    num_experts_per_tok=2,
    moe_dense_residual=True,
    moe_dense_d_ff=4864,
    router_aux_loss=0.01,
    tie_embeddings=False,
    moe_impl="ep",
    act_shard="seq",
)
