"""xlstm-125m [ssm]: sLSTM + mLSTM blocks (arXiv:2405.04517).

12 layers at ratio ~7:1 mLSTM:sLSTM (period-8 pattern, sLSTM at index 7).
d_ff=0 per the assignment: xLSTM blocks carry their own up/down projections.
The mLSTM matrix memory is O(1) in sequence length -> long_500k runs.
"""
from .base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_mode="none",
    block_pattern=(MLSTM,) * 7 + (SLSTM,),
    tie_embeddings=True,
    scan_layers=False,
)
