"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a frozen ``ModelConfig``. The
reduced smoke-test variants are derived with ``cfg.smoke()`` so a single
source of truth holds the published hyper-parameters.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

# ---------------------------------------------------------------------------
# Block kinds used by hybrid / ssm architectures.
ATTN = "attn"  # full / local self attention block
MLSTM = "mlstm"  # xLSTM matrix-memory block
SLSTM = "slstm"  # xLSTM scalar-memory block
RECUR = "recur"  # RG-LRU (Griffin) recurrent block


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    # -- core dims ---------------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # -- attention ---------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_mode: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # temporal / h / w (pairs)
    attn_window: int = 0  # 0 = full attention; >0 = sliding window
    attn_logit_softcap: float = 0.0
    # -- ffn ---------------------------------------------------------------
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    moe_dense_d_ff: int = 0
    router_aux_loss: float = 0.0
    moe_impl: str = "gather"  # gather (GSPMD-global) | ep (shard_map expert-parallel)
    # -- encoder/decoder (whisper) ------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500  # audio frame positions after the (stubbed) conv
    # -- hybrid / ssm ------------------------------------------------------
    block_pattern: Tuple[str, ...] = ()  # () -> all ATTN; else tiled to depth
    lru_width: int = 0  # RG-LRU hidden width (0 -> d_model)
    conv_width: int = 4  # temporal conv for recurrent blocks
    # -- vlm / audio stub frontends ------------------------------------------
    frontend: str = "none"  # none | audio_stub | vision_stub
    # -- embeddings ----------------------------------------------------------
    tie_embeddings: bool = True
    # -- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # -- training -------------------------------------------------------------
    remat: str = "full"  # none | full | dots (checkpoint policy)
    scan_layers: bool = True
    act_shard: str = "none"  # none | seq: residual stream sharded over "model"
    #   ("sequence parallelism": saved activations shrink |model|-fold; GSPMD
    #   turns the surrounding collectives into all-gather/reduce-scatter)

    # -- derived -------------------------------------------------------------
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def q_dim(self) -> int:
        return self.num_heads * self.hd()

    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd()

    def pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds, tiled to ``num_layers``."""
        if not self.block_pattern:
            return (ATTN,) * self.num_layers
        p = self.block_pattern
        reps = (self.num_layers + len(p) - 1) // len(p)
        return (p * reps)[: self.num_layers]

    def pattern_period(self) -> Tuple[str, ...]:
        return self.block_pattern if self.block_pattern else (ATTN,)

    def is_subquadratic(self) -> bool:
        """True if the arch can decode at 0.5M context (no full-attn KV)."""
        kinds = set(self.pattern())
        if ATTN in kinds and self.attn_window == 0:
            return False
        if self.is_encoder_decoder:
            return False
        return True

    def has_decode(self) -> bool:
        """Encoder-only models have no decode step. All ours decode."""
        return True

    # -- param counting (for roofline MODEL_FLOPS = 6*N*D) --------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd()
        qd, kvd = self.q_dim(), self.kv_dim()
        embed = self.vocab_size * d
        unembed = 0 if self.tie_embeddings else self.vocab_size * d

        def attn_block() -> int:
            n = d * qd + 2 * d * kvd + qd * d
            if self.qkv_bias:
                n += qd + 2 * kvd
            return n + 2 * d  # 2 norms approx

        def ffn(dff: int) -> int:
            if dff == 0:
                return 0
            if self.act == "silu":
                return 3 * d * dff
            return 2 * d * dff

        def moe_block() -> int:
            n = d * self.num_experts  # router
            e = self.num_experts if not active_only else self.num_experts_per_tok
            n += e * ffn(self.d_ff)
            n += self.num_shared_experts * ffn(self.shared_d_ff or self.d_ff)
            if self.moe_dense_residual:
                n += ffn(self.moe_dense_d_ff or self.d_ff)
            return n

        def mlstm_block() -> int:
            # up-proj x2, q/k/v over inner dim, gates, out-proj (pf = 2)
            inner = 2 * d
            return 2 * d * inner + 3 * inner * inner // 2 + inner * d + 4 * inner

        def slstm_block() -> int:
            # 4 gates, recurrent + input weights, ffn-ish projection (pf 4/3)
            return 8 * d * d + int(2 * 4 / 3 * d * d)

        def recur_block() -> int:
            w = self.lru_width or d
            return 2 * d * w + w * d + self.conv_width * w + 2 * w * w + 2 * w

        total = embed + unembed + d  # final norm
        for kind in self.pattern():
            if kind == ATTN:
                total += attn_block()
                if self.num_experts:
                    total += moe_block()
                else:
                    total += ffn(self.d_ff)
            elif kind == MLSTM:
                total += mlstm_block()
            elif kind == SLSTM:
                total += slstm_block()
            elif kind == RECUR:
                total += recur_block() + ffn(self.d_ff)
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                total += attn_block() + ffn(self.d_ff)
            # decoder cross attention
            total += self.num_layers * attn_block()
        return int(total)

    # -- reduced variant for CPU smoke tests ---------------------------------
    def smoke(self) -> "ModelConfig":
        d = 64
        n_heads = min(self.num_heads, 4)
        n_kv = min(self.num_kv_heads, n_heads)
        period = self.pattern_period()
        layers = max(2, len(period))
        updates: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            encoder_seq=16,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            lru_width=0,
            attn_window=min(self.attn_window, 8) if self.attn_window else 0,
            mrope_sections=(2, 3, 3),  # sums to head_dim // 2 = 8
            dtype="float32",
            param_dtype="float32",
            remat="none",
        )
        if self.num_experts:
            updates.update(
                num_experts=8,
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                num_shared_experts=min(self.num_shared_experts, 2),
                shared_d_ff=128 if self.shared_d_ff else 0,
                moe_dense_d_ff=128 if self.moe_dense_residual else 0,
            )
        return dataclasses.replace(self, **updates)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and the reason when skipped."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, "full quadratic attention: 0.5M-token decode skipped per assignment"
    return True, ""
