"""whisper-base [audio]: enc-dec transformer backbone (arXiv:2212.04356).

Conv audio frontend is a stub: input_specs() provides (B, 1500, 512) frame
embeddings. 6L encoder + 6L decoder, MHA (kv=8), LayerNorm + GELU.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    qkv_bias=True,
    rope_mode="none",
    act="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
    tie_embeddings=True,
    scan_layers=False,
)
