"""Scenario engine: composable EnvParams transforms + named stress suites.

See ``registry`` (the Scenario spec, transform registry and severity-grid
expansion), ``transforms`` (the built-in event families, each with a
declared severity knob) and ``suites`` (named suites and ``build_grid``
severity grids, sized for the batched day engine — one
``repro.core.experiment`` compile per technique).
"""
from . import transforms  # noqa: F401  (imports register the built-ins)
from .registry import (Scenario, Transform, apply_all, compose, expand_grid,
                       get, make, names, register, severity_knob)
from .suites import (SUITES, build_grid, build_month, build_suite,
                     suite_names)

__all__ = [
    "Scenario", "Transform", "apply_all", "compose", "expand_grid", "get",
    "make", "names", "register", "severity_knob", "SUITES", "build_grid",
    "build_month", "build_suite", "suite_names",
]
