"""Scenario engine: composable EnvParams transforms + named stress suites.

See ``registry`` (the Scenario spec and transform registry), ``transforms``
(the ≥7 built-in event families) and ``suites`` (named suites sized for the
batched day engine ``repro.core.schedulers.run_days_batched``).
"""
from . import transforms  # noqa: F401  (imports register the built-ins)
from .registry import (Scenario, Transform, apply_all, compose, get, make,
                       names, register)
from .suites import SUITES, build_month, build_suite, suite_names

__all__ = [
    "Scenario", "Transform", "apply_all", "compose", "get", "make", "names",
    "register", "SUITES", "build_month", "build_suite", "suite_names",
]
