"""Scenario registry: named, parameterized, composable EnvParams transforms.

A *transform* is a pure function ``EnvParams -> EnvParams`` (same shapes and
dtypes in and out, deterministic given its parameters — any randomness is
driven by an explicit ``seed`` parameter). A *factory* builds a transform
from keyword parameters; factories are registered by name so scenarios can
be specified, serialized and round-tripped as plain ``(name, params)`` data
(the ``Scenario`` spec below), then composed into named suites
(``repro.scenarios.suites``).

    >>> t = make("flash_crowd", start=18, duration=3, magnitude=3.0)
    >>> stressed = t(env)                       # pure, repeatable
    >>> s = Scenario("dc_outage", {"dc": 0})
    >>> s.apply(env)                            # round-trips by name
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional, Tuple

from ..dcsim.env import EnvParams

Transform = Callable[[EnvParams], EnvParams]
Factory = Callable[..., Transform]

_REGISTRY: Dict[str, Factory] = {}
_SEVERITY: Dict[str, str] = {}


def register(name: str,
             severity: Optional[str] = None) -> Callable[[Factory], Factory]:
    """Decorator: register a transform factory under ``name``.

    ``severity`` names the factory's canonical severity knob — the one
    parameter a magnitude grid sweeps (``wan_degradation``'s ``factor``,
    ``origin_shift``'s ``weight``, …) — so ``expand_grid`` can accept bare
    scalars for this transform.
    """
    def deco(factory: Factory) -> Factory:
        if name in _REGISTRY:
            raise KeyError(f"scenario transform {name!r} already registered")
        _REGISTRY[name] = factory
        if severity is not None:
            _SEVERITY[name] = severity
        return factory
    return deco


def severity_knob(name: str) -> str:
    """The registered transform's canonical severity parameter name."""
    get(name)  # raise the unknown-transform error, not a knob error
    try:
        return _SEVERITY[name]
    except KeyError:
        raise ValueError(
            f"transform {name!r} declares no severity knob; "
            "pass explicit params dicts in the grid instead") from None


def get(name: str) -> Factory:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario transform {name!r}; known: {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make(name: str, **params: Any) -> Transform:
    """Build the named transform with ``params`` (round-trip of a spec)."""
    return get(name)(**params)


def compose(*transforms: Transform) -> Transform:
    """Left-to-right composition: compose(f, g)(env) == g(f(env))."""
    def composed(env: EnvParams) -> EnvParams:
        for t in transforms:
            env = t(env)
        return env
    return composed


class Scenario(NamedTuple):
    """Serializable (name, params) spec for one registered transform."""
    name: str
    params: Mapping[str, Any] = {}

    def build(self) -> Transform:
        return make(self.name, **dict(self.params))

    def apply(self, env: EnvParams) -> EnvParams:
        return self.build()(env)


def apply_all(env: EnvParams, scenarios) -> EnvParams:
    """Apply a sequence of Scenario specs (or transforms) in order."""
    for s in scenarios:
        env = s.apply(env) if isinstance(s, Scenario) else s(env)
    return env


def expand_grid(grid: Mapping[str, Any]) -> list:
    """Expand a severity grid into the cartesian list of grid points.

    ``grid`` maps a registered transform name to a sequence of points; a
    point is either a params dict (passed to the factory verbatim) or a
    bare scalar for the transform's declared severity knob::

        expand_grid({"wan_degradation": (1.0, 3.0),
                     "origin_shift": ({"weight": 0.8, "toward": (0,)},)})
        # -> [{"wan_degradation": {"factor": 1.0},
        #      "origin_shift": {"weight": 0.8, "toward": (0,)}}, ...]

    Axes combine in insertion order (the first axis varies slowest); each
    returned point is an ordered ``{name: params}`` dict, directly
    convertible to a ``Scenario`` list.
    """
    import itertools

    axes = []
    for name, pts in grid.items():
        get(name)  # unknown transforms fail before any env is built
        norm = []
        for p in pts:
            if isinstance(p, Mapping):
                norm.append((name, dict(p)))
            else:
                norm.append((name, {severity_knob(name): p}))
        axes.append(norm)
    return [dict(combo) for combo in itertools.product(*axes)]
