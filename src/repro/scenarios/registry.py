"""Scenario registry: named, parameterized, composable EnvParams transforms.

A *transform* is a pure function ``EnvParams -> EnvParams`` (same shapes and
dtypes in and out, deterministic given its parameters — any randomness is
driven by an explicit ``seed`` parameter). A *factory* builds a transform
from keyword parameters; factories are registered by name so scenarios can
be specified, serialized and round-tripped as plain ``(name, params)`` data
(the ``Scenario`` spec below), then composed into named suites
(``repro.scenarios.suites``).

    >>> t = make("flash_crowd", start=18, duration=3, magnitude=3.0)
    >>> stressed = t(env)                       # pure, repeatable
    >>> s = Scenario("dc_outage", {"dc": 0})
    >>> s.apply(env)                            # round-trips by name
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, NamedTuple, Tuple

from ..dcsim.env import EnvParams

Transform = Callable[[EnvParams], EnvParams]
Factory = Callable[..., Transform]

_REGISTRY: Dict[str, Factory] = {}


def register(name: str) -> Callable[[Factory], Factory]:
    """Decorator: register a transform factory under ``name``."""
    def deco(factory: Factory) -> Factory:
        if name in _REGISTRY:
            raise KeyError(f"scenario transform {name!r} already registered")
        _REGISTRY[name] = factory
        return factory
    return deco


def get(name: str) -> Factory:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario transform {name!r}; known: {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make(name: str, **params: Any) -> Transform:
    """Build the named transform with ``params`` (round-trip of a spec)."""
    return get(name)(**params)


def compose(*transforms: Transform) -> Transform:
    """Left-to-right composition: compose(f, g)(env) == g(f(env))."""
    def composed(env: EnvParams) -> EnvParams:
        for t in transforms:
            env = t(env)
        return env
    return composed


class Scenario(NamedTuple):
    """Serializable (name, params) spec for one registered transform."""
    name: str
    params: Mapping[str, Any] = {}

    def build(self) -> Transform:
        return make(self.name, **dict(self.params))

    def apply(self, env: EnvParams) -> EnvParams:
        return self.build()(env)


def apply_all(env: EnvParams, scenarios) -> EnvParams:
    """Apply a sequence of Scenario specs (or transforms) in order."""
    for s in scenarios:
        env = s.apply(env) if isinstance(s, Scenario) else s(env)
    return env
