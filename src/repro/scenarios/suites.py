"""Named scenario suites: ordered maps of scenario-day name → Scenario list.

A suite row composes registered transforms (left to right) onto a base env;
``build_suite`` materializes the envs, all with identical shapes so they can
be stacked and evaluated in one compile by ``schedulers.run_days_batched``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from ..dcsim.env import EnvParams
from .registry import Scenario, apply_all, expand_grid, severity_knob

# Each suite: name -> ordered {scenario_day: [Scenario, ...]}.
SUITES: Dict[str, Dict[str, List[Scenario]]] = {
    # the paper's protocol: resampled arrival days, nothing else
    "baseline": {
        f"resample-{r}": [Scenario("arrival_resample", {"seed": r})]
        for r in range(5)
    },
    # grid-side events only (scheduler sees unchanged traffic)
    "grid_events": {
        "carbon-spike": [Scenario("carbon_spike", {"start": 6, "duration": 8, "magnitude": 2.5})],
        "carbon-diurnal": [Scenario("carbon_diurnal", {"amplitude": 0.35})],
        "price-surge": [Scenario("price_surge", {"start": 14, "duration": 6, "magnitude": 2.2})],
        "renewable-drought": [Scenario("renewable_drought", {"scale": 0.1})],
        "demand-response": [Scenario("demand_response", {"dc": 1, "start": 16, "duration": 4, "curtail": 0.6})],
    },
    # the SLA/latency family: misses priced, WAN and capacity under stress
    # (evaluate with objective="cost_sla" so schedulers see the new term)
    "latency": {
        "sla-baseline": [Scenario("sla_tighten")],
        "sla-tight": [Scenario("sla_tighten", {"tighten": 0.6})],
        "wan-degraded": [
            Scenario("sla_tighten"),
            Scenario("wan_degradation", {"factor": 3.0, "extra_ms": 30.0}),
        ],
        "sla-flash-crowd": [
            Scenario("sla_tighten", {"tighten": 0.8}),
            Scenario("flash_crowd", {"start": 18, "duration": 4, "magnitude": 3.0}),
        ],
        "sla-curtailed": [
            Scenario("sla_tighten", {"tighten": 0.8}),
            Scenario("demand_response", {"dc": 1, "start": 14, "duration": 6, "curtail": 0.6}),
        ],
        "sla-wan-crunch": [
            Scenario("sla_tighten", {"tighten": 0.7}),
            Scenario("wan_degradation", {"factor": 2.0, "extra_ms": 15.0}),
            Scenario("flash_crowd", {"start": 17, "duration": 5, "magnitude": 2.0}),
        ],
    },
    # per-source routing family: SLA priced + WAN visible + demand origins
    # shifted/regionalized, so the (source → DC) split is worth optimizing
    # (evaluate with objective="cost_sla" and routed=True engines; source
    # indices assume the 4-DC fleet: 0=NY, 1=SF, 2=Dallas, 3=Seattle)
    "routing": {
        "uniform-origin": [
            Scenario("sla_tighten", {"tighten": 0.6}),
            Scenario("wan_degradation", {"factor": 3.0, "extra_ms": 30.0}),
        ],
        "east-business-day": [
            Scenario("sla_tighten", {"tighten": 0.6}),
            Scenario("wan_degradation", {"factor": 3.0, "extra_ms": 30.0}),
            Scenario("origin_shift", {"toward": [0], "weight": 0.7,
                                      "start": 12, "duration": 10}),
        ],
        "west-evening": [
            Scenario("sla_tighten", {"tighten": 0.6}),
            Scenario("wan_degradation", {"factor": 3.0, "extra_ms": 30.0}),
            Scenario("origin_shift", {"toward": [1, 3], "weight": 0.7,
                                      "start": 0, "duration": 8}),
        ],
        "regional-flash-crowd": [
            Scenario("sla_tighten", {"tighten": 0.7}),
            Scenario("wan_degradation", {"factor": 2.0, "extra_ms": 20.0}),
            Scenario("flash_crowd", {"start": 18, "duration": 4,
                                     "magnitude": 2.5, "sources": [0]}),
        ],
        "shifted-wan-crunch": [
            Scenario("sla_tighten", {"tighten": 0.6}),
            Scenario("wan_degradation", {"factor": 4.0, "extra_ms": 40.0}),
            Scenario("origin_shift", {"toward": [0], "weight": 0.8}),
            Scenario("demand_response", {"dc": 0, "start": 14, "duration": 6,
                                         "curtail": 0.5}),
        ],
    },
    # the full stress family: traffic, infrastructure and grid events
    "stress": {
        "baseline": [Scenario("identity")],
        "flash-crowd": [Scenario("flash_crowd", {"start": 18, "duration": 4, "magnitude": 3.0})],
        "dc-outage": [Scenario("dc_outage", {"dc": 0, "start": 8, "duration": 6})],
        "carbon-spike": [Scenario("carbon_spike", {"start": 6, "duration": 8, "magnitude": 2.5})],
        "price-surge": [Scenario("price_surge", {"start": 14, "duration": 6, "magnitude": 2.2})],
        "renewable-drought": [Scenario("renewable_drought", {"scale": 0.1})],
        "demand-response": [Scenario("demand_response", {"dc": 1, "start": 16, "duration": 4, "curtail": 0.6})],
        "weekend": [Scenario("traffic_pattern", {"kind": "weekend", "seed": 3})],
        "bursty": [Scenario("traffic_pattern", {"kind": "bursty", "seed": 4})],
        "grid-crunch": [
            Scenario("carbon_spike", {"start": 12, "duration": 8, "magnitude": 2.0}),
            Scenario("price_surge", {"start": 12, "duration": 8, "magnitude": 1.8}),
            Scenario("renewable_drought", {"scale": 0.2}),
        ],
        "crowd-plus-outage": [
            Scenario("flash_crowd", {"start": 17, "duration": 5, "magnitude": 2.5}),
            Scenario("dc_outage", {"dc": 2, "start": 17, "duration": 5}),
        ],
    },
}


def suite_names() -> Tuple[str, ...]:
    return tuple(SUITES)


def build_suite(name: str, base_env: EnvParams) -> List[Tuple[str, EnvParams]]:
    """Materialize (scenario_day, env) rows for the named suite."""
    try:
        rows = SUITES[name]
    except KeyError:
        raise KeyError(f"unknown suite {name!r}; known: {suite_names()}") from None
    return [(day, apply_all(base_env, scenarios)) for day, scenarios in rows.items()]


def _point_label(point) -> str:
    """Compact "name=value|…" label for one grid point (severity knob value
    when declared, the full params dict otherwise)."""
    parts = []
    for name, params in point.items():
        try:
            v = params.get(severity_knob(name))
        except ValueError:
            v = None
        parts.append(f"{name}={v if v is not None else params}")
    return "|".join(parts)


def build_grid(base_env: EnvParams, grid, *, base=()) -> Tuple[list, List[Tuple[str, EnvParams]]]:
    """Materialize a severity grid: ``(points, rows)``.

    ``grid`` is the ``registry.expand_grid`` grammar — transform name ->
    sequence of params dicts or bare severity-knob scalars; the cartesian
    product becomes one scenario-day per point. ``base`` scenarios (or
    transforms) apply to ``base_env`` first, before every point — e.g. an
    ``sla_tighten`` row so every grid point prices misses. All rows share
    the base env's shapes, so the whole grid stacks into ONE batched-engine
    compile (``repro.core.experiment.sweep`` drives exactly this).
    """
    points = expand_grid(grid)
    env0 = apply_all(base_env, base)
    rows = [(_point_label(pt),
             apply_all(env0, [Scenario(n, p) for n, p in pt.items()]))
            for pt in points]
    return points, rows


def build_month(base_env: EnvParams, days: int = 30, *,
                seed: int = 0) -> List[Tuple[str, EnvParams]]:
    """Per-day (name, env) rows for a month-scale episode.

    A simple calendar: weekday traffic Mon–Fri, the weekend shape on days 5
    and 6 of each week, and every day's arrivals independently resampled
    (the paper's run-to-run 20%-std variation) so no two days are identical.
    Feed the env column to ``schedulers.run_month``, which threads the
    monthly peak-demand state across the stacked days.
    """
    rows = []
    for d in range(days):
        kind = "weekend" if d % 7 >= 5 else "weekday"
        scens = [Scenario("traffic_pattern", {"kind": kind, "seed": seed}),
                 Scenario("arrival_resample", {"seed": seed + 100 + d})]
        rows.append((f"day{d:02d}-{kind}", apply_all(base_env, scens)))
    return rows
