"""The scenario transform library: grid, infrastructure and traffic events.

Each factory returns a pure ``EnvParams -> EnvParams`` transform (the stress
families benchmarked in DCcluster-Opt, arXiv:2511.00117, and the perturbed
heterogeneous regimes of Green-LLM, arXiv:2507.09942):

- ``flash_crowd``        traffic surge in an hour window (× magnitude);
                         ``sources=`` makes it regional (origin tilts there)
- ``dc_outage``          one DC's capacity zeroed for a window (avail mask)
- ``carbon_spike``       grid carbon-intensity surge in a window
- ``carbon_diurnal``     marginal-carbon dip at local midday (solar on grid)
- ``price_surge``        TOU price surge in a window (grid scarcity event)
- ``renewable_drought``  on-site renewables scaled down (becalmed/overcast)
- ``demand_response``    partial capacity curtailment in a window
- ``traffic_pattern``    rebuild arrivals from a named workload pattern
- ``arrival_resample``   the paper's per-run normal resampling of arrivals
- ``sla_tighten``        enable/tighten SLA targets and price misses
- ``wan_degradation``    inter-region RTT inflated (congestion/reroute event)
- ``origin_shift``       demand origins tilted toward given source regions
- ``identity``           no-op (baseline rows in suites)

Windows are ``[start, start+duration)`` in UTC hours, wrapping modulo 24.
All randomness flows through an explicit ``seed`` so a transform is a fixed
function of its parameters; shapes and dtypes are always preserved.

These are *planned* (briefed) events: a transform edits the ``EnvParams``
the solvers plan on, so the scheduler sees the event coming and routes
around it from hour 0 — ``dc_outage`` models a maintenance window on the
calendar. Disruptions that arrive *during execution*, with the planner
still optimizing the healthy env, are the other half of robustness and
live in ``repro.faults`` (``FaultTrace`` + ``run(..., faults=...)``): same
physical events, applied to the realized env view inside the engine while
the plan stays blind.

Each registration declares its canonical *severity knob* (``severity=`` on
``@register``): the one parameter a magnitude grid sweeps — so severity
sweeps (``repro.core.experiment.sweep`` / ``scenarios.build_grid``) can say
``{"wan_degradation": (1.0, 2.0, 4.0)}`` and mean the ``factor`` axis.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..dcsim import latency, workload
from ..dcsim.env import EnvParams
from .registry import Transform, register


def _window(start: int, duration: int) -> np.ndarray:
    """(24,) float mask for [start, start+duration) mod 24."""
    h = np.arange(24)
    return (((h - start) % 24) < duration).astype(np.float64)


def _rows(n: int, which: Optional[Sequence[int]]) -> np.ndarray:
    """(n,) float row-selection mask (None = all rows)."""
    m = np.zeros(n) if which is not None else np.ones(n)
    if which is not None:
        m[np.asarray(which)] = 1.0
    return m


def _scale_field(arr: jnp.ndarray, row_mask: np.ndarray, hour_mask: np.ndarray,
                 factor: float) -> jnp.ndarray:
    """Multiply arr (R, 24) by ``factor`` on selected rows × hours."""
    mult = 1.0 + (factor - 1.0) * np.outer(row_mask, hour_mask)
    return jnp.asarray(np.asarray(arr) * mult, arr.dtype)


def _clip01(avail) -> jnp.ndarray:
    """Keep the EnvParams invariant avail ∈ [0, 1] whatever the params."""
    avail = jnp.asarray(avail)
    return jnp.clip(avail, 0.0, 1.0).astype(avail.dtype)


@register("identity")
def identity() -> Transform:
    return lambda env: env


@register("flash_crowd", severity="magnitude")
def flash_crowd(start: int = 18, duration: int = 3, magnitude: float = 3.0,
                tasks: Optional[Sequence[int]] = None,
                sources: Optional[Sequence[int]] = None) -> Transform:
    """Traffic surge: arrivals × magnitude in the window (all or some types).

    ``sources`` makes the surge *regional*: the extra demand originates at
    the given source regions (a stadium event, a regional launch), so the
    window's ``origin`` split tilts toward them — total origin mass per
    (task, hour) stays 1. Default (None) keeps the surge origin-neutral.
    """
    def t(env: EnvParams) -> EnvParams:
        mask = _rows(env.car.shape[0], tasks)
        hour = _window(start, duration)
        out = env._replace(
            car=_scale_field(env.car, mask, hour, magnitude))
        if sources is not None:
            # mult (I, 24): the same per-cell factor applied to car; the
            # surge's (mult - 1)·car extra demand all lands on ``sources``
            mult = 1.0 + (magnitude - 1.0) * np.outer(mask, hour)
            origin = np.asarray(env.origin, dtype=float)      # (S, I, 24)
            src = np.zeros(origin.shape[0])
            src[np.asarray(sources)] = 1.0 / len(sources)
            shifted = ((origin + (mult - 1.0)[None] * src[:, None, None])
                       / np.maximum(mult, 1e-9)[None])
            # a regional *dip* (magnitude < 1) can't drain a source below
            # zero — clamp and renormalize so origin stays a distribution
            # (at magnitude 0 the window has no arrivals; origin is moot)
            shifted = np.clip(shifted, 0.0, None)
            shifted = shifted / shifted.sum(axis=0, keepdims=True)
            out = out._replace(origin=jnp.asarray(shifted, env.origin.dtype))
        return out
    return t


@register("dc_outage", severity="duration")
def dc_outage(dc: int = 0, start: int = 8, duration: int = 6) -> Transform:
    """Full outage of one DC for the window: avail → 0 (capacity, IT power
    and idle draw all vanish; project_feasible sheds its load elsewhere).

    This is the *planned* outage — solvers see the dark window in their
    ``EnvParams`` and never schedule onto it. For the unplanned version
    (the planner keeps allocating to a DC that actually crashed, and a
    failover policy re-projects at execution time) use
    ``repro.faults.dc_crash`` with ``run(..., faults=...)``."""
    def t(env: EnvParams) -> EnvParams:
        row = _rows(env.avail.shape[0], (dc,))
        off = np.outer(row, _window(start, duration))
        return env._replace(avail=_clip01(env.avail * (1.0 - off)))
    return t


@register("demand_response", severity="curtail")
def demand_response(dc: int = 0, start: int = 16, duration: int = 4,
                    curtail: float = 0.5) -> Transform:
    """Demand-response event: the DC sheds ``curtail`` of its capacity."""
    def t(env: EnvParams) -> EnvParams:
        row = _rows(env.avail.shape[0], (dc,))
        cut = 1.0 - curtail * np.outer(row, _window(start, duration))
        return env._replace(avail=_clip01(env.avail * cut))
    return t


@register("carbon_spike", severity="magnitude")
def carbon_spike(start: int = 6, duration: int = 6, magnitude: float = 2.5,
                 dcs: Optional[Sequence[int]] = None) -> Transform:
    """Grid carbon-intensity surge (e.g. coal peakers online) in the window."""
    def t(env: EnvParams) -> EnvParams:
        mask = _rows(env.carbon.shape[0], dcs)
        return env._replace(
            carbon=_scale_field(env.carbon, mask, _window(start, duration), magnitude))
    return t


@register("carbon_diurnal", severity="amplitude")
def carbon_diurnal(amplitude: float = 0.35, trough_utc: int = 20) -> Transform:
    """Marginal-carbon diurnal shape: intensity dips ``amplitude`` at
    ``trough_utc`` (solar-heavy afternoon grid) and rises overnight."""
    def t(env: EnvParams) -> EnvParams:
        h = np.arange(24)
        shape = 1.0 + amplitude * np.cos((h - trough_utc) / 24.0 * 2 * np.pi + np.pi)
        carbon = np.asarray(env.carbon) * shape[None, :]
        return env._replace(carbon=jnp.asarray(carbon, env.carbon.dtype))
    return t


@register("price_surge", severity="magnitude")
def price_surge(start: int = 14, duration: int = 6, magnitude: float = 2.0,
                dcs: Optional[Sequence[int]] = None) -> Transform:
    """TOU price surge (grid scarcity / heat event) in the window."""
    def t(env: EnvParams) -> EnvParams:
        mask = _rows(env.eprice.shape[0], dcs)
        return env._replace(
            eprice=_scale_field(env.eprice, mask, _window(start, duration), magnitude))
    return t


@register("renewable_drought", severity="scale")
def renewable_drought(scale: float = 0.15, start: int = 0, duration: int = 24,
                      dcs: Optional[Sequence[int]] = None) -> Transform:
    """Becalmed/overcast day: on-site renewables scaled to ``scale``."""
    def t(env: EnvParams) -> EnvParams:
        mask = _rows(env.rp.shape[0], dcs)
        return env._replace(
            rp=_scale_field(env.rp, mask, _window(start, duration), scale))
    return t


@register("traffic_pattern")
def traffic_pattern(kind: str = "weekday", seed: int = 0,
                    utilization: float = 0.45) -> Transform:
    """Rebuild arrivals from a named workload pattern (weekday/weekend/
    bursty/flat/sinusoidal) against the env's actual capacity — the one
    source of truth is ``workload.base_rates`` / ``arrival_pattern``."""
    def t(env: EnvParams) -> EnvParams:
        cap = np.asarray(env.er).sum(axis=1)
        base = workload.base_rates(cap, utilization)
        car = workload.arrival_pattern(kind, base, seed=seed)
        return env._replace(car=jnp.asarray(car, env.car.dtype))
    return t


@register("sla_tighten", severity="tighten")
def sla_tighten(tighten: float = 1.0, price: float = 1e-4,
                weight: Optional[float] = None,
                tasks: Optional[Sequence[int]] = None) -> Transform:
    """Turn the SLA term on: scale the selected tasks' SLA targets by
    ``tighten`` (<1 = stricter) and charge ``price`` $/task per expected
    miss. ``weight`` optionally overrides the ``cost_sla`` objective weight.
    Defaults leave the targets at build_env's slack values, so this is also
    the canonical "enable SLA pricing" switch for suites."""
    def t(env: EnvParams) -> EnvParams:
        mask = _rows(env.sla_ms.shape[0], tasks)
        sla_ms = np.asarray(env.sla_ms) * (1.0 + (tighten - 1.0) * mask)
        sla_price = np.where(mask > 0, price, np.asarray(env.sla_price))
        out = env._replace(sla_ms=jnp.asarray(sla_ms, env.sla_ms.dtype),
                           sla_price=jnp.asarray(sla_price, env.sla_price.dtype))
        if weight is not None:
            out = out._replace(
                sla_weight=jnp.asarray(weight, env.sla_weight.dtype))
        return out
    return t


@register("wan_degradation", severity="factor")
def wan_degradation(factor: float = 3.0, extra_ms: float = 20.0) -> Transform:
    """WAN congestion/reroute event: inter-region RTTs × ``factor`` plus
    ``extra_ms`` of queueing delay on every off-diagonal (cross-region)
    path. A zero (paper-default) RTT matrix is first seeded from the
    canonical ``topology.location_coords`` geometry, so the transform
    composes onto default envs and onto already-degraded ones alike.
    ``rtt`` is always the canonical (D, D) matrix, so ``extra_ms`` lands
    exactly on cross-region paths (the old (D,)-vector form smeared it with
    a scalar (d-1)/d factor, mispricing every path)."""
    def t(env: EnvParams) -> EnvParams:
        rtt = np.asarray(env.rtt, dtype=float)
        if rtt.ndim != 2:
            raise ValueError(
                f"rtt must be the canonical (D, D) matrix, got {rtt.shape}")
        d = rtt.shape[-1]
        if not rtt.any():
            rtt = latency.rtt_matrix(num_dcs=d)
        rtt = rtt * factor + extra_ms * (1.0 - np.eye(d))
        return env._replace(rtt=jnp.asarray(rtt, env.rtt.dtype))
    return t


@register("origin_shift", severity="weight")
def origin_shift(toward: Sequence[int] = (0,), weight: float = 0.8,
                 start: int = 0, duration: int = 24,
                 tasks: Optional[Sequence[int]] = None) -> Transform:
    """Shift the demand-origin split toward the given source regions.

    In the window, the selected tasks' origins become the convex blend
    ``(1 - weight) · origin + weight · uniform(toward)`` — e.g. a US-east
    business day (``toward`` = the east-coast regions) or a regional market
    launch. Mass per (task, hour) stays 1 over sources; only ``origin``
    changes, so the unrouted model is blind to this event — exactly the gap
    per-source routing closes.
    """
    def t(env: EnvParams) -> EnvParams:
        origin = np.asarray(env.origin, dtype=float)          # (S, I, 24)
        target = np.zeros(origin.shape[0])
        target[np.asarray(toward)] = 1.0 / len(toward)
        w = weight * np.outer(_rows(origin.shape[1], tasks),
                              _window(start, duration))       # (I, 24)
        shifted = (1.0 - w)[None] * origin + w[None] * target[:, None, None]
        return env._replace(origin=jnp.asarray(shifted, env.origin.dtype))
    return t


@register("arrival_resample", severity="std")
def arrival_resample(seed: int = 0, std: float = 0.2) -> Transform:
    """The paper's run-to-run variation: CAR ~ N(CAR, std·CAR), clipped."""
    def t(env: EnvParams) -> EnvParams:
        car = workload.resample_car(np.asarray(env.car), seed, std)
        return env._replace(car=jnp.asarray(car, env.car.dtype))
    return t


@register("workload_mix_shift", severity="weight")
def workload_mix_shift(toward: Sequence[int] = (0,), weight: float = 0.5,
                       start: int = 0, duration: int = 24) -> Transform:
    """Shift the *workload mix* toward the given task types / model families.

    In the window, each hour's arrivals become the convex blend
    ``(1 - weight) · car + weight · (hourly total on uniform(toward))`` —
    total arrivals per hour are preserved, but their composition tilts (a
    chat-model launch day, an image-gen fad). This is the workload-mix
    severity axis orthogonal to grid events: under the llm capability layer
    the targets are model families with very different tokens/sec and
    J/token, so the same total traffic can demand radically different
    fleets. Workload-agnostic (any ``I``).
    """
    def t(env: EnvParams) -> EnvParams:
        car = np.asarray(env.car, dtype=float)                # (I, 24)
        target = np.zeros(car.shape[0])
        target[np.asarray(toward)] = 1.0 / len(toward)
        w = weight * _window(start, duration)                  # (24,)
        total = car.sum(axis=0, keepdims=True)                 # (1, 24)
        shifted = (1.0 - w)[None] * car + w[None] * target[:, None] * total
        return env._replace(car=jnp.asarray(shifted, env.car.dtype))
    return t


@register("context_length_surge", severity="factor")
def context_length_surge(factor: float = 2.0,
                         tasks: Optional[Sequence[int]] = None) -> Transform:
    """Requests get ``factor``× longer (prompts + outputs) for the selected
    task types — a long-document season, an agentic-trace regime shift.

    The honest EnvParams-level approximation of a token-length shift: the
    per-request work scales with the tokens served, so the selected rows'
    execution rate ``er`` divides by ``factor`` (service time in the M/M/c
    model is ``3.6e6 / er`` ms — it stretches by exactly ``factor``) and the
    per-request network payload ``sizes`` multiplies by it. Whole-day (no
    window): ``er`` is static per env — sweep the factor axis for a
    severity curve. Workload-agnostic, though the factor is only *derived*
    under the llm capability layer's token units.
    """
    def t(env: EnvParams) -> EnvParams:
        rows = _rows(env.er.shape[0], tasks)                   # (I,)
        er_scale = np.where(rows > 0, 1.0 / factor, 1.0)
        sz_scale = np.where(rows > 0, factor, 1.0)
        return env._replace(
            er=env.er * jnp.asarray(er_scale, env.er.dtype)[:, None],
            sizes=env.sizes * jnp.asarray(sz_scale, env.sizes.dtype))
    return t
