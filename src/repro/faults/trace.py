"""``FaultTrace``: seedable, composable *realized* event streams.

A trace is what actually happened to the fleet during the day, as opposed
to what the planner was told (``EnvParams``). The scenario transforms in
``repro.scenarios`` bake events into the env the solvers *plan on* —
``scenarios.dc_outage`` is an outage the scheduler saw coming and routed
around from hour 0. A ``FaultTrace`` is the complement: the solvers keep
planning on the unfaulted env, and the execution layer
(``repro.faults.failover``) applies the trace to a realized env view each
hour *inside* the jitted scan, re-projecting the planner's allocation
against realized capacity. That plan/execute split is what DCcluster-Opt
(PAPERS.md) argues robustness benchmarks need: disruptions that arrive
during execution, not in the briefing.

The trace is a pytree of hourly multipliers/addends over the planner's
fields, so it jits, vmaps (one trace shared across a batched env fleet) and
composes (multipliers multiply, RTT penalties add):

======================  =========  =======================================
field                   shape      meaning (realized = planned ∘ trace)
======================  =========  =======================================
``avail_mult``          (D, 24)    realized avail = avail · avail_mult
``rtt_extra_ms``        (D, D, 24) realized rtt = rtt + rtt_extra_ms[..., t]
``price_mult``          (D, 24)    realized $/kWh = eprice · price_mult
``carbon_mult``         (D, 24)    realized kg/kWh = carbon · carbon_mult
======================  =========  =======================================

Event constructors: ``dc_crash`` (hard capacity zero), ``brownout``
(partial capacity loss), ``wan_partition`` (an inter-region link degrades),
``telemetry_dropout`` (the planner's price/carbon feed went stale — the
realized signal differs by a factor). ``random_trace`` samples a seeded
mix. ``no_faults`` is the identity trace: engines fed it produce the
unfaulted numbers (bit-for-bit on the unrouted path).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

HOURS = 24


class FaultTrace(NamedTuple):
    """Realized per-hour fault multipliers the executor applies to the
    planning env (shapes pinned in ``repro.lint.pytrees.SCHEMAS``).

    Machine-read unit table (repro.lint.units):

        avail_mult: 1
        rtt_extra_ms: ms
        price_mult: 1
        carbon_mult: 1
    """
    avail_mult: jnp.ndarray    # (D, 24) in [0, 1]
    rtt_extra_ms: jnp.ndarray  # (D, D, 24) >= 0
    price_mult: jnp.ndarray    # (D, 24) > 0
    carbon_mult: jnp.ndarray   # (D, 24) > 0


def _ndc(env_or_d) -> int:
    """Number of DCs from an EnvParams or a bare int."""
    if isinstance(env_or_d, (int, np.integer)):
        return int(env_or_d)
    return int(env_or_d.er.shape[-1])


def _window(start: int, duration: int) -> np.ndarray:
    """(24,) float mask for [start, start+duration) mod 24 (the scenario
    transforms' convention)."""
    h = np.arange(HOURS)
    return (((h - start) % HOURS) < duration).astype(np.float64)


def _f32(x) -> jnp.ndarray:
    return jnp.asarray(np.asarray(x, dtype=np.float32))


def no_faults(env_or_d) -> FaultTrace:
    """The identity trace: nothing happened. Engines under it reproduce
    the unfaulted planner numbers (bit-for-bit on the unrouted path; the
    routed failover re-split is allclose — see ``failover.apply_failover``)."""
    d = _ndc(env_or_d)
    return FaultTrace(
        avail_mult=_f32(np.ones((d, HOURS))),
        rtt_extra_ms=_f32(np.zeros((d, d, HOURS))),
        price_mult=_f32(np.ones((d, HOURS))),
        carbon_mult=_f32(np.ones((d, HOURS))),
    )


def dc_crash(env_or_d, dc: int = 0, start: int = 12,
             duration: int = 6) -> FaultTrace:
    """Hard crash: the DC's realized capacity is zero for the window. The
    planner still schedules onto it; the failover policy decides where that
    mass goes."""
    t = no_faults(env_or_d)
    mult = np.array(t.avail_mult)
    mult[dc] = 1.0 - _window(start, duration)
    return t._replace(avail_mult=_f32(mult))


def brownout(env_or_d, dc: int = 0, start: int = 10, duration: int = 8,
             severity: float = 0.5) -> FaultTrace:
    """Capacity brownout: the DC loses ``severity`` of its realized
    capacity in the window (thermal event, partial grid curtailment)."""
    t = no_faults(env_or_d)
    mult = np.array(t.avail_mult)
    mult[dc] = 1.0 - severity * _window(start, duration)
    return t._replace(avail_mult=_f32(mult))


def wan_partition(env_or_d, a: int = 0, b: int = 1, start: int = 0,
                  duration: int = 24, extra_ms: float = 500.0) -> FaultTrace:
    """Link partition/degradation: the a↔b inter-region path gains
    ``extra_ms`` of realized RTT both directions for the window (a severed
    or congested backbone segment). Affects realized SLA pricing and the
    ``spill_nearest`` failover geometry."""
    t = no_faults(env_or_d)
    extra = np.array(t.rtt_extra_ms)
    w = _window(start, duration) * float(extra_ms)
    extra[a, b] += w
    extra[b, a] += w
    return t._replace(rtt_extra_ms=_f32(extra))


def telemetry_dropout(env_or_d, dc: Optional[int] = None, start: int = 0,
                      duration: int = 24, price_factor: float = 1.0,
                      carbon_factor: float = 1.0) -> FaultTrace:
    """Stale telemetry: the planner's price/carbon feed for ``dc`` (all DCs
    when None) stopped updating, and reality drifted by the given factors —
    realized $/kWh = planned · price_factor, realized intensity = planned ·
    carbon_factor in the window. The plan is costed at what the grid
    actually charged/emitted, not what the stale feed claimed."""
    t = no_faults(env_or_d)
    rows = slice(None) if dc is None else dc
    w = _window(start, duration)
    price = np.array(t.price_mult)
    carbon = np.array(t.carbon_mult)
    price[rows] = 1.0 + (price_factor - 1.0) * w
    carbon[rows] = 1.0 + (carbon_factor - 1.0) * w
    return t._replace(price_mult=_f32(price), carbon_mult=_f32(carbon))


def compose(*traces: FaultTrace) -> FaultTrace:
    """Overlay traces: availability/price/carbon multipliers multiply,
    RTT penalties add. Order-independent."""
    if not traces:
        raise ValueError("compose() needs at least one trace")
    out = traces[0]
    for t in traces[1:]:
        out = FaultTrace(
            avail_mult=out.avail_mult * t.avail_mult,
            rtt_extra_ms=out.rtt_extra_ms + t.rtt_extra_ms,
            price_mult=out.price_mult * t.price_mult,
            carbon_mult=out.carbon_mult * t.carbon_mult,
        )
    return out


def stack_traces(traces: Sequence[FaultTrace]) -> FaultTrace:
    """Stack per-point traces leaf-wise into one batched FaultTrace.

    The leading axis lines up with a batched engine's env rows (one realized
    day of trouble per scenario/grid point); ``run``/``sweep`` detect the
    extra axis and vmap the trace alongside the envs instead of replicating
    one shared trace.
    """
    traces = list(traces)
    if not traces:
        raise ValueError("stack_traces() needs at least one trace")
    shapes = {t.avail_mult.shape for t in traces}
    if len(shapes) != 1:
        raise ValueError(f"traces disagree on (D, hours): {sorted(shapes)}")
    return FaultTrace(*(jnp.stack([getattr(t, f) for t in traces])
                        for f in FaultTrace._fields))


_KINDS = ("dc_crash", "brownout", "wan_partition", "telemetry_dropout")


def random_trace(env_or_d, seed: int = 0, n_events: int = 3,
                 kinds: Sequence[str] = _KINDS) -> FaultTrace:
    """A seeded random day of trouble: ``n_events`` events drawn from
    ``kinds`` with randomized targets/windows/severities. Deterministic in
    ``seed`` — the same trace replays across techniques and sweeps."""
    d = _ndc(env_or_d)
    rng = np.random.default_rng(seed)
    parts = [no_faults(d)]
    for _ in range(n_events):
        kind = kinds[int(rng.integers(len(kinds)))]
        start = int(rng.integers(0, HOURS))
        duration = int(rng.integers(2, 13))
        if kind == "dc_crash":
            parts.append(dc_crash(d, dc=int(rng.integers(d)), start=start,
                                  duration=duration))
        elif kind == "brownout":
            parts.append(brownout(d, dc=int(rng.integers(d)), start=start,
                                  duration=duration,
                                  severity=float(rng.uniform(0.2, 0.8))))
        elif kind == "wan_partition":
            a, b = rng.choice(d, size=2, replace=False)
            parts.append(wan_partition(d, a=int(a), b=int(b), start=start,
                                       duration=duration,
                                       extra_ms=float(rng.uniform(100, 800))))
        elif kind == "telemetry_dropout":
            parts.append(telemetry_dropout(
                d, dc=int(rng.integers(d)), start=start, duration=duration,
                price_factor=float(rng.uniform(0.5, 2.5)),
                carbon_factor=float(rng.uniform(0.5, 2.5))))
        else:
            raise ValueError(f"unknown fault kind {kind!r}; known: {_KINDS}")
    return compose(*parts)
