"""``repro.faults`` — the realized-fault execution layer.

Three pieces, one contract: **``faults=None`` engines are bit-for-bit the
unfaulted programs** (faultedness joins the compile key; the default
artifacts never contain any of this).

- **FaultTrace** (``repro.faults.trace``): seedable, composable *realized*
  event streams — DC hard-crash, capacity brownout, WAN link partition,
  price/carbon telemetry dropout — as a jittable pytree of hourly
  multipliers over the planner's ``EnvParams``. Solvers keep planning on
  the unfaulted env; the trace is what actually happened.
- **Failover execution** (``repro.faults.failover``): inside the jitted
  scan, each hour builds the realized env view, re-projects the planner's
  allocation against realized capacity via a policy (``renormalize |
  spill_nearest | drop``) and simulates the epoch there, emitting
  ``unserved_demand`` / ``failover_moved`` / ``degraded_sla_cost_usd``
  through the engines' totals, taps and RunRecords.
- **Graceful degradation + resume** (``repro.faults.guard`` /
  ``repro.faults.resume``): finite-guards on solver outputs with a
  compiled fallback to the capacity-proportional baseline (surfaced as a
  ``fallback_hours`` counter), and the journal/supervisor plumbing behind
  ``sweep(..., resume_dir=...)`` — per-chunk completion checkpoints,
  resume-after-kill, bounded retry with exponential backoff, per-chunk
  wall timeouts.

Typical use::

    from repro import faults
    from repro.core import ExperimentSpec, run

    trace = faults.compose(faults.dc_crash(env, dc=1, start=12, duration=6),
                           faults.wan_partition(env, a=0, b=1))
    res = run(ExperimentSpec(technique="gt-drl",
                             failover="spill_nearest"), env, faults=trace)
    res["totals"]["unserved_demand"], res["totals"]["failover_moved"]
"""
from .failover import (DEFAULT_POLICY, POLICIES, apply_failover, execute_hour,
                       realized_env)
from .guard import guard_fractions
from .resume import (KilledMidSweep, PointTimeout, SweepJournal,
                     call_with_timeout, check_kill_switch, inject_kill_after)
from .trace import (FaultTrace, brownout, compose, dc_crash, no_faults,
                    random_trace, stack_traces, telemetry_dropout,
                    wan_partition)

__all__ = [
    "FaultTrace", "no_faults", "dc_crash", "brownout", "wan_partition",
    "telemetry_dropout", "compose", "random_trace", "stack_traces",
    "POLICIES", "DEFAULT_POLICY", "realized_env", "apply_failover",
    "execute_hour", "guard_fractions",
    "SweepJournal", "KilledMidSweep", "PointTimeout", "call_with_timeout",
    "check_kill_switch", "inject_kill_after",
]
