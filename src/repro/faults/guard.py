"""Numerical graceful degradation: finite-guards on solver outputs.

A diverged solver (NaN/Inf logits after a bad gradient step, a degenerate
all-zero simplex row) must cost a metric — ``fallback_hours`` — not a
crashed or silently-poisoned run. ``guard_fractions`` is compiled into the
faulted engines (and any spec with ``guard=True``): when the hour's joint
strategy is non-finite or degenerate it is replaced wholesale by the
capacity-proportional allocation — the ``fd`` baseline's natural feasible
starting point (``game.capacity_fractions``) — and the hour is counted.

The fallback is computed unconditionally (it is a handful of FLOPs against
a solver step's thousands) and selected with ``jnp.where``, because a
``lax.cond`` under ``vmap`` lowers to a select that runs both branches
anyway. Engines without ``guard`` compile none of this — the ``faults=None``
default program is untouched.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ..dcsim import env as E

_EPS = 1e-6


def guard_fractions(env: E.EnvParams, tau,
                    fractions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return ``(fractions, fell_back)``: the solver's joint strategy if
    every entry is finite and every simplex row carries mass, else the
    capacity-proportional fallback; ``fell_back`` is 1.0 on fallback hours
    (summed into the ``fallback_hours`` total by the engines)."""
    er_t = E.capacity_at(env, tau)
    base = er_t / jnp.maximum(jnp.sum(er_t, axis=1, keepdims=True), 1e-9)
    fallback = jnp.broadcast_to(base, fractions.shape)
    ok = (jnp.all(jnp.isfinite(fractions))
          & jnp.all(jnp.sum(fractions, axis=-1) > _EPS))
    return jnp.where(ok, fractions, fallback), jnp.where(ok, 0.0, 1.0)
