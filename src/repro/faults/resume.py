"""Resumable, retrying sweep execution: the journal + supervisor plumbing.

``core.experiment.sweep(..., resume_dir=...)`` drives grid points in
chunks through three previously-dead seed components, wired here:

- ``checkpoint.manager.CheckpointManager`` — each completed chunk's result
  arrays are saved as one atomic checkpoint step (npz per field,
  MANIFEST.json written last). The set of manifested steps IS the
  per-point completion journal: a sweep killed mid-grid re-opens the
  directory, loads the completed steps back and computes only the rest.
- ``distributed.fault_tolerance.run_with_retries`` — the supervisor loop:
  a chunk dispatch that raises is retried (bounded, exponential backoff)
  from the journal's frontier instead of aborting the sweep.
- ``distributed.fault_tolerance.HeartbeatMonitor`` — per-chunk wall times
  feed the straggler detector; the sweep result reports chunks whose
  median step time is an outlier (a pathological grid point, a thermal
  throttle).

``call_with_timeout`` bounds each chunk's wall time (a hung compile fails
the chunk — and then the retry/backoff path — instead of hanging the
sweep). ``inject_kill_after`` is the deterministic mid-sweep "kill -9"
used by tests, ``make faults-smoke`` and the resume example;
``KilledMidSweep`` derives from ``BaseException`` so the supervisor's
retry net never catches it — exactly like a real process death.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional

import numpy as np

from ..checkpoint.manager import CheckpointManager


class PointTimeout(RuntimeError):
    """A grid-point chunk exceeded its wall-time budget."""


class KilledMidSweep(BaseException):
    """Simulated hard kill (test/demo injection). BaseException on purpose:
    the retry supervisor catches Exceptions; a kill must escape it."""


_KILL_COUNTDOWN: Optional[int] = None


@contextlib.contextmanager
def inject_kill_after(n_chunks: int):
    """Within the context, the sweep dies (``KilledMidSweep``) right before
    dispatching its ``n_chunks``-th+1 chunk — after ``n_chunks`` completed
    chunks have hit the journal. Deterministic resume-after-kill testing."""
    global _KILL_COUNTDOWN
    prev = _KILL_COUNTDOWN
    _KILL_COUNTDOWN = int(n_chunks)
    try:
        yield
    finally:
        _KILL_COUNTDOWN = prev


def check_kill_switch() -> None:
    """Called by the sweep before each chunk dispatch."""
    global _KILL_COUNTDOWN
    if _KILL_COUNTDOWN is None:
        return
    if _KILL_COUNTDOWN <= 0:
        raise KilledMidSweep("injected mid-sweep kill")
    _KILL_COUNTDOWN -= 1


def call_with_timeout(fn: Callable[[], Any], timeout_s: Optional[float],
                      label: str = "chunk") -> Any:
    """Run ``fn()`` with a wall-time bound. Raises ``PointTimeout`` when it
    does not return in time (the worker thread is daemonic and abandoned —
    a hung XLA compile cannot be cancelled, only failed past)."""
    if not timeout_s:
        return fn()
    box: Dict[str, Any] = {}

    def target():
        try:
            box["out"] = fn()
        except BaseException as e:  # noqa: BLE001 - re-raised on the caller
            box["err"] = e

    th = threading.Thread(target=target, daemon=True)
    th.start()
    th.join(timeout_s)
    if th.is_alive():
        raise PointTimeout(f"{label} exceeded its {timeout_s}s wall budget")
    if "err" in box:
        raise box["err"]
    return box["out"]


def _unflatten(named: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """Rebuild nested dicts from the checkpoint's path-keyed npz entries
    (``"totals/carbon_kg"`` -> ``out["totals"]["carbon_kg"]``)."""
    out: Dict[str, Any] = {}
    for path, arr in named.items():
        parts = path.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = np.asarray(arr)
    return out


class SweepJournal:
    """Per-chunk completion journal over ``CheckpointManager``.

    One checkpoint step per completed chunk (``keep=0``: never GC'd —
    every step is load-bearing state, not a rollback point). A step's
    ``extra`` carries the sweep signature; reopening a journal against a
    different grid/spec raises instead of silently mixing results.
    """

    def __init__(self, directory: str, signature: str):
        self.mgr = CheckpointManager(directory, keep=0)
        self.signature = signature
        for step in self.mgr.steps():
            extra = self._extra(step)
            if extra.get("signature") != signature:
                raise ValueError(
                    f"journal {directory!r} step {step} belongs to a "
                    "different sweep (signature "
                    f"{extra.get('signature')!r} != {signature!r}); "
                    "point resume_dir at a fresh directory")

    def _extra(self, step: int) -> dict:
        import json
        import os
        d = f"{self.mgr.directory}/step_{step:09d}"
        with open(os.path.join(d, "MANIFEST.json")) as f:
            return json.load(f).get("extra", {})

    def completed_steps(self):
        return self.mgr.steps()

    def next_step(self) -> int:
        """The execution frontier: chunks run in order, so the journal is
        always a prefix and the first missing step is where to resume."""
        done = set(self.completed_steps())
        step = 0
        while step in done:
            step += 1
        return step

    def mark(self, step: int, result: Dict[str, Any],
             meta: Optional[dict] = None) -> None:
        """Atomically journal one completed chunk's result arrays."""
        extra = {"signature": self.signature, **(meta or {})}
        self.mgr.save(step, {"result": result}, extra=extra)

    def load(self, step: int, verify: bool = True) -> Dict[str, Any]:
        """Load one journaled chunk's result arrays back (sha-verified)."""
        import os
        d = os.path.join(self.mgr.directory, f"step_{step:09d}")
        fpath = os.path.join(d, "result.npz")
        if verify:
            import json

            from ..checkpoint.manager import _sha256
            with open(os.path.join(d, "MANIFEST.json")) as f:
                manifest = json.load(f)
            if manifest["files"]["result"]["sha256"] != _sha256(fpath):
                raise IOError(f"journal step {step}: result.npz sha256 "
                              "mismatch (corrupt)")
        with np.load(fpath) as data:
            return _unflatten({k: data[k] for k in data.files})
