"""Realized-hour execution: the plan/execute split's execute side.

``execute_hour`` is what the faulted engines run instead of a bare
``E.step_epoch``: build the hour's *realized* env view from the trace,
re-project the planner's allocation against realized capacity via a
failover policy, then simulate the epoch on the realized env. Everything
is plain jittable array math — it runs inside the engines' ``lax.scan``.

Failover policies (what operators actually do when a DC goes dark under
load):

- ``renormalize``  — shed the over-capacity mass and redistribute it to
  DCs with headroom in proportion to that headroom (the global load
  balancer rebalances; no locality preference).
- ``spill_nearest`` — redistribute headroom-proportionally *weighted by
  realized network nearness* ``1 / (1 + rtt / SPILL_RTT_SCALE_MS)``: mass
  spills to close healthy DCs first, which is cheaper on the realized SLA
  bill but can saturate neighbors. With an all-zero RTT matrix (the paper
  default) this degenerates to ``renormalize``.
- ``drop``         — no failover: over-capacity mass is simply unserved
  (what happens when the failover automation itself is down).

Degradation metrics appended to the epoch's dict (and summed into the
result totals by the engines):

- ``unserved_demand``       tasks/h the realized fleet could not serve;
- ``failover_moved``        tasks/h served at a DC the planner did not
  pick (mass moved by the policy);
- ``degraded_sla_cost_usd`` realized SLA bill minus what the plan would
  have paid on the unfaulted env (can be negative under ``drop``: dropped
  requests pay no SLA charge — they show up in ``unserved_demand``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..dcsim import env as E
from .trace import FaultTrace

POLICIES = ("renormalize", "spill_nearest", "drop")
DEFAULT_POLICY = "renormalize"

SPILL_RTT_SCALE_MS = 25.0   # nearness kernel scale for spill_nearest  # lint: unit(ms)
REDISTRIBUTE_ROUNDS = 4     # water-fill rounds (project_feasible's budget)

_EPS = 1e-9


def realized_env(env: E.EnvParams, trace: FaultTrace, tau) -> E.EnvParams:
    """The hour's realized env view: planner fields composed with the trace.

    ``avail``/``eprice``/``carbon`` carry their own hourly axis so the full
    (D, 24) products are formed (only column ``tau`` is consumed
    downstream); ``rtt`` is per-hour, indexed here.
    """
    return env._replace(
        avail=env.avail * trace.avail_mult,
        eprice=env.eprice * trace.price_mult,
        carbon=env.carbon * trace.carbon_mult,
        rtt=env.rtt + trace.rtt_extra_ms[:, :, tau],
    )


def _nearness(renv: E.EnvParams, policy: str) -> jnp.ndarray:
    """(D, D) redistribution kernel K[from, to] for the water-fill."""
    d = E.num_dcs(renv)
    if policy == "spill_nearest":
        return 1.0 / (1.0 + renv.rtt / SPILL_RTT_SCALE_MS)
    return jnp.ones((d, d))


def _redistribute(kept: jnp.ndarray, over: jnp.ndarray, cap: jnp.ndarray,
                  kern: jnp.ndarray) -> jnp.ndarray:
    """Iteratively place homeless mass ``over`` (I, D; tagged by the DC it
    was shed from) into headroom, weighted by headroom × kernel. Mass that
    finds no headroom after ``REDISTRIBUTE_ROUNDS`` stays unserved."""
    def body(carry, _):
        kept, over = carry
        head = jnp.maximum(cap - kept, 0.0)                       # (I, D)
        w = head[:, None, :] * kern[None, :, :]                   # (I, Df, Dt)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), _EPS)
        inc = jnp.einsum("if,ift->it", over, w)                   # (I, D)
        acc = jnp.minimum(inc, head)
        return (kept + acc, inc - acc), None

    (kept, _), _ = jax.lax.scan(body, (kept, over), None,
                                length=REDISTRIBUTE_ROUNDS)
    return kept


def apply_failover(renv: E.EnvParams, ar: jnp.ndarray, tau,
                   policy: str = DEFAULT_POLICY
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Re-project a planned allocation against *realized* capacity.

    ``ar`` is the planner's (I, D) allocation or routed (S, I, D) tensor.
    Returns ``(ar_realized, unserved, moved)`` — realized same-shape
    allocation, total unserved tasks/h, total tasks/h moved off-plan.

    Routed tensors fail over on their (I, D) totals (capacity is
    source-blind), then each realized cell splits across sources by the
    planned per-source share; mass moved into cells the plan left empty
    splits by the hour's demand-origin mix (``project_feasible_routed``'s
    convention).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown failover policy {policy!r}; "
                         f"known: {POLICIES}")
    ar3 = ar if ar.ndim == 3 else None
    tot = jnp.sum(ar3, axis=0) if ar3 is not None else ar      # (I, D)
    cap = E.capacity_at(renv, tau)                             # (I, D)
    kept0 = jnp.minimum(tot, cap)
    if policy == "drop":
        kept = kept0
    else:
        kept = _redistribute(kept0, tot - kept0, cap,
                             _nearness(renv, policy))
    # clamped: at 1e9-scale allocations the float32 reductions can land a
    # few hundred tasks/h on either side of zero
    unserved = jnp.maximum(jnp.sum(tot) - jnp.sum(kept), 0.0)
    moved = jnp.maximum(jnp.sum(kept - kept0), 0.0)
    if ar3 is None:
        return kept, unserved, moved
    origin = E.origin_at(renv, tau)                            # (S, I)
    share = jnp.where(tot[None] > _EPS,
                      ar3 / jnp.maximum(tot[None], _EPS),
                      origin[:, :, None])
    return kept[None] * share, unserved, moved


def execute_hour(env: E.EnvParams, trace: FaultTrace, peak_state, ar, tau,
                 policy: str = DEFAULT_POLICY):
    """One realized epoch: failover the planned ``ar`` against the hour's
    realized env, simulate it there, and append the degradation metrics.

    The planner's own SLA bill (planned ``ar`` on the unfaulted ``env``) is
    recomputed here so ``degraded_sla_cost_usd`` is a pure delta — the cost
    of being surprised, not of the SLA terms existing at all.
    """
    renv = realized_env(env, trace, tau)
    ar_r, unserved, moved = apply_failover(renv, ar, tau, policy)
    peak_state, m = E.step_epoch(renv, peak_state, ar_r, tau)
    if ar.ndim == 3:
        planned_sla = jnp.sum(E.sla_cost_routed(env, ar, tau))
    else:
        planned_sla = jnp.sum(E.sla_cost(env, ar, tau))
    m["unserved_demand"] = unserved
    m["failover_moved"] = moved
    m["degraded_sla_cost_usd"] = m["sla_miss_cost_usd"] - planned_sla
    return peak_state, m
