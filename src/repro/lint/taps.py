"""Tap-name registry: every ``obs.tap("...")`` literal must name a
declared tap.

Tap liveness is decided at trace time by *string* match against the active
tap set, so a typo'd tap name is the quietest possible failure: the call
compiles to nothing, nothing ever streams, and no test fails unless one
specifically awaited that name. The registry closes the loop statically:

- ``KNOWN_TAPS`` in ``repro.obs.tap`` declares every tap name;
- every ``tap(<literal>, ...)`` call site must use a declared name, and
  the first argument must *be* a string literal (a computed name defeats
  the registry, and the engine compile caches key on tap-set tuples that
  assume names are static);
- every declared name must be emitted somewhere (a stale registry entry is
  a lie to anyone enabling that tap);
- tap *pattern* literals (``obs.taps("engine/*")``, ``enable_taps``,
  ``ExperimentSpec(taps=...)``) must match at least one declared tap — the
  same typo class, one level up.

The registry itself is parsed from the AST, never imported.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from .project import Project, Violation

TAP_MODULE = "repro.obs.tap"
REGISTRY_NAME = "KNOWN_TAPS"

#: call names whose string-literal args are tap *patterns*
PATTERN_CALLS = ("taps", "enable_taps")


def declared_taps(project: Project) -> Tuple[Optional[Set[str]], Optional[int]]:
    """Parse ``KNOWN_TAPS = ("...", ...)`` out of ``repro.obs.tap``.
    Returns (names, assignment line), or (None, None) if missing."""
    sf = project.module(TAP_MODULE)
    if sf is None or sf.tree is None:
        return None, None
    for node in sf.tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        if not any(isinstance(t, ast.Name) and t.id == REGISTRY_NAME
                   for t in targets):
            continue
        value = node.value
        if isinstance(value, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in value.elts):
            return {e.value for e in value.elts}, node.lineno
        return None, node.lineno
    return None, None


def _pattern_matches(pattern: str, names: Set[str]) -> bool:
    if pattern == "*":
        return bool(names)
    if pattern.endswith("/*"):
        prefix = pattern[:-1]          # keep the slash
        return any(n.startswith(prefix) for n in names)
    return pattern in names


def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    known, reg_line = declared_taps(project)
    tap_sf = project.module(TAP_MODULE)
    if known is None:
        rel = tap_sf.relpath if tap_sf else "src/repro/obs/tap.py"
        out.append(Violation(
            rel, reg_line or 1, "taps",
            f"`{REGISTRY_NAME}` is missing from `{TAP_MODULE}` (or is not "
            "a literal tuple of strings) — the tap registry must be a "
            "statically readable declaration"))
        return out

    emitted: Set[str] = set()
    for rel, sf in project.sources.items():
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name == "tap":
                if not node.args:
                    continue   # not the obs.tap signature; leave to runtime
                first = node.args[0]
                if not (isinstance(first, ast.Constant)
                        and isinstance(first.value, str)):
                    out.append(Violation(
                        rel, node.lineno, "taps",
                        "tap name must be a string literal — a computed "
                        "name cannot be checked against the registry and "
                        "breaks the static tap-set compile keys"))
                    continue
                emitted.add(first.value)
                if first.value not in known:
                    out.append(Violation(
                        rel, node.lineno, "taps",
                        f"tap name {first.value!r} is not declared in "
                        f"`{TAP_MODULE}.{REGISTRY_NAME}` — an undeclared "
                        "tap can be typo'd into silence; declare it (known: "
                        f"{sorted(known)})"))
            elif name in PATTERN_CALLS:
                for arg in node.args:
                    if isinstance(arg, ast.Constant) and \
                            isinstance(arg.value, str) and \
                            not _pattern_matches(arg.value, known):
                        out.append(Violation(
                            rel, arg.lineno, "taps",
                            f"tap pattern {arg.value!r} matches no "
                            f"declared tap (known: {sorted(known)}) — it "
                            "would enable nothing, silently"))

    # declared but never emitted: the registry must not over-promise.
    # (tap.py itself only *declares*; emission lives at the instrumented
    # sites, so this scan covers exactly the emitting modules.)
    for name in sorted(known - emitted):
        out.append(Violation(
            tap_sf.relpath, reg_line, "taps",
            f"declared tap {name!r} is never emitted by any "
            "`tap(...)` call in the scanned tree — delete it from "
            f"{REGISTRY_NAME} or wire up the emission"))
    return out
