"""Pytree contracts: declared shape/dtype schemas for the NamedTuple
pytrees that cross the engine boundary.

``EnvParams``, ``FaultTrace`` and ``CapabilityBundle`` are the repo's data
planes — every solver, engine and fault path consumes them positionally and
by leaf shape. The schemas below pin, per field, the symbolic shape
(``I`` task types × ``D`` data centers × ``S`` demand sources × literal
``24`` hours) and the leaf kind, and are enforced twice:

- **statically** (``check``): the class declaration must match the schema
  field-for-field in order (adding a field forces a schema update here,
  which is the point — the schema is the reviewable contract), and every
  construction site must be *total*: keyword construction must pass every
  field exactly once, positional construction must cover the full arity.
  A partial construction is how a new field silently picks up a wrong
  default.
- **at runtime** (``validate``, opt-in): leaf ndim/shape unification
  against the symbolic dims, plus the two dtype hazards that fork compile
  caches — float64 leaves (an x64-enabled build quietly doubles every
  artifact) and weak-typed leaves (a ``jnp.full(..., 1.0)`` literal whose
  weak type forks the cache the first time it meets a strongly-typed
  operand).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .project import Project, Violation

Dim = Union[str, int]

#: leaf kinds: jnp float32 on-device array | host-side numpy array | opaque
ARRAY, HOST, OPAQUE = "array", "host", "opaque"


class FieldSpec:
    def __init__(self, dims: Sequence[Dim], kind: str = ARRAY):
        self.dims = tuple(dims)
        self.kind = kind

    def render(self) -> str:
        return "(" + ", ".join(str(d) for d in self.dims) + ")"


#: class name -> (defining module, ordered field schemas)
SCHEMAS: Dict[str, Tuple[str, Dict[str, FieldSpec]]] = {
    "EnvParams": ("repro.dcsim.env", {
        "er":         FieldSpec(("I", "D")),
        "it_idle":    FieldSpec(("D",)),
        "it_dyn":     FieldSpec(("D",)),
        "tsupply":    FieldSpec(("D",)),
        "eff":        FieldSpec(("D",)),
        "rp":         FieldSpec(("D", 24)),
        "carbon":     FieldSpec(("D", 24)),
        "eprice":     FieldSpec(("D", 24)),
        "peak_price": FieldSpec(("D",)),
        "alpha":      FieldSpec(("D",)),
        "nprice":     FieldSpec(()),
        "sizes":      FieldSpec(("I",)),
        "nn_total":   FieldSpec(("D",)),
        "car":        FieldSpec(("I", 24)),
        "avail":      FieldSpec(("D", 24)),
        "rtt":        FieldSpec(("D", "D")),
        "sla_ms":     FieldSpec(("I",)),
        "sla_price":  FieldSpec(("I",)),
        "sla_weight": FieldSpec(()),
        "origin":     FieldSpec(("S", "I", 24)),
    }),
    "FaultTrace": ("repro.faults.trace", {
        "avail_mult":   FieldSpec(("D", 24)),
        "rtt_extra_ms": FieldSpec(("D", "D", 24)),
        "price_mult":   FieldSpec(("D", 24)),
        "carbon_mult":  FieldSpec(("D", 24)),
    }),
    "CapabilityBundle": ("repro.dcsim.capability", {
        "task_names": FieldSpec(("I",), OPAQUE),   # tuple of labels
        "er":         FieldSpec(("I", "D"), HOST),
        "it_idle":    FieldSpec(("D",), HOST),
        "it_dyn":     FieldSpec(("D",), HOST),
        "nn_total":   FieldSpec(("D",), HOST),
        "sizes":      FieldSpec(("I",), HOST),
        "sla_ms":     FieldSpec(("I",), HOST),
        "meta":       FieldSpec((), OPAQUE),
    }),
}


# ---------------------------------------------------------------------------
# static side
# ---------------------------------------------------------------------------

def _class_fields(tree: ast.Module, cls: str) -> Optional[List[Tuple[str, int]]]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [(n.target.id, n.lineno) for n in node.body
                    if isinstance(n, ast.AnnAssign)
                    and isinstance(n.target, ast.Name)]
    return None


def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []

    # 1. class declarations still match the schemas (field names AND order)
    for cls, (module, schema) in SCHEMAS.items():
        sf = project.module(module)
        if sf is None or sf.tree is None:
            out.append(Violation(
                f"src/{module.replace('.', '/')}.py", 1, "pytree",
                f"module `{module}` (declares {cls}) is missing or "
                "unparseable — its pytree contract is unverifiable"))
            continue
        declared = _class_fields(sf.tree, cls)
        if declared is None:
            out.append(Violation(
                sf.relpath, 1, "pytree",
                f"class `{cls}` not found in `{module}` — update the "
                "schema in repro.lint.pytrees if it moved"))
            continue
        names = [n for n, _ in declared]
        if names != list(schema):
            extra = [n for n in names if n not in schema]
            missing = [n for n in schema if n not in names]
            line = declared[0][1] if declared else 1
            detail = []
            if extra:
                detail.append(f"fields {extra} have no schema entry")
            if missing:
                detail.append(f"schema fields {missing} are gone")
            if not detail:
                detail.append(f"field order changed to {names}")
            out.append(Violation(
                sf.relpath, line, "pytree",
                f"`{cls}` drifted from its declared schema: "
                + "; ".join(detail)
                + " — update SCHEMAS in repro.lint.pytrees to match "
                "(the schema is the reviewed contract)"))

    # 2. construction sites are total
    for rel, sf in project.sources.items():
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            cls = _call_name(node)
            if cls not in SCHEMAS:
                continue
            schema = SCHEMAS[cls][1]
            fields = list(schema)
            if any(isinstance(a, ast.Starred) for a in node.args) or \
                    any(kw.arg is None for kw in node.keywords):
                continue   # *splat / **kwargs: arity is not statically known
            pos = len(node.args)
            kws = [kw.arg for kw in node.keywords]
            dupes = sorted({k for k in kws if kws.count(k) > 1
                            or k in fields[:pos]})
            unknown = sorted(k for k in kws if k not in fields)
            covered = set(fields[:pos]) | set(kws)
            missing = [f for f in fields if f not in covered]
            if pos > len(fields):
                out.append(Violation(
                    rel, node.lineno, "pytree",
                    f"`{cls}` constructed with {pos} positional args but "
                    f"has {len(fields)} fields"))
            elif unknown:
                out.append(Violation(
                    rel, node.lineno, "pytree",
                    f"`{cls}` constructed with unknown fields {unknown} — "
                    "not in its schema"))
            elif dupes:
                out.append(Violation(
                    rel, node.lineno, "pytree",
                    f"`{cls}` construction binds {dupes} twice"))
            elif missing:
                out.append(Violation(
                    rel, node.lineno, "pytree",
                    f"`{cls}` construction is partial: {missing} not "
                    "passed — every field must be bound explicitly so a "
                    "new field cannot silently pick up a stale default"))
    return out


# ---------------------------------------------------------------------------
# runtime side (opt-in; the only part that touches live arrays)
# ---------------------------------------------------------------------------

def validate(tree, name: Optional[str] = None) -> None:
    """Validate a live pytree instance against its declared schema.

    Checks per-leaf ndim, unification of the symbolic dims (every ``D``
    the same size, literal ``24`` exact), and — for on-device leaves —
    the two compile-cache-forking dtype hazards: float64 and weak types.
    Raises ``TypeError`` with every failure listed; returns the tree so it
    can be used inline: ``env = lint.validate(build_env(4))``.
    """
    cls = name or type(tree).__name__
    if cls not in SCHEMAS:
        raise TypeError(
            f"no pytree schema declared for {cls!r}; known: "
            f"{sorted(SCHEMAS)}")
    schema = SCHEMAS[cls][1]
    errors: List[str] = []
    bind: Dict[str, int] = {}
    for field, spec in schema.items():
        leaf = getattr(tree, field, None)
        if leaf is None:
            errors.append(f"{field}: missing")
            continue
        if spec.kind == OPAQUE:
            continue
        shape = getattr(leaf, "shape", None)
        if shape is None:
            errors.append(f"{field}: expected an array, got "
                          f"{type(leaf).__name__}")
            continue
        if len(shape) != len(spec.dims):
            errors.append(f"{field}: shape {tuple(shape)} has ndim "
                          f"{len(shape)}, schema says {spec.render()}")
        else:
            for dim, got in zip(spec.dims, shape):
                if isinstance(dim, int):
                    if got != dim:
                        errors.append(
                            f"{field}: shape {tuple(shape)} != schema "
                            f"{spec.render()}")
                        break
                elif bind.setdefault(dim, got) != got:
                    errors.append(
                        f"{field}: dim {dim}={got} contradicts "
                        f"{dim}={bind[dim]} bound earlier — the pytree's "
                        "axes disagree")
                    break
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and dtype.kind == "f" and dtype.itemsize > 4:
            errors.append(
                f"{field}: dtype {dtype} — float64 leaves double every "
                "compile-cache artifact (x64 crept in upstream)")
        if spec.kind == ARRAY and getattr(leaf, "weak_type", False):
            errors.append(
                f"{field}: weak-typed leaf — a bare-Python-literal array "
                "(e.g. jnp.full(..., 1.0)) forks the compile cache when it "
                "meets a strongly-typed operand; build it with an explicit "
                "dtype")
    if errors:
        raise TypeError(
            f"{cls} violates its pytree schema:\n  " + "\n  ".join(errors))
    return tree
