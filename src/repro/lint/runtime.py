"""Runtime compile-count sanitizer, riding ``obs.cache_stats()``.

The static checkers prove the compile *key* is complete; this module proves
the *cache behaves*: a block of work builds exactly the artifacts it should
and re-running it builds none. Two layers, because they catch different
regressions:

- :func:`expect_compiles` watches the repo's own accounting (``misses`` in
  ``obs.cache_stats()``) — a miss delta above the expectation means a key
  started forking (e.g. an unhashed config leaked into the tuple), below
  means something is being served stale.
- :func:`trace_count` asks **jax itself** how many times a spec's live
  artifact has traced (``jit``'s internal cache size). The repo accounting
  cannot see a silent retrace *inside* one artifact — e.g. a weak-typed
  operand forking the jit cache under a single engine key — but the jit
  cache can.

Everything imports lazily so ``repro.lint``'s static side stays
importable without jax.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator, Optional


@contextmanager
def expect_compiles(n: int, *, exact: bool = True) -> Iterator[dict]:
    """Assert the block compiles exactly ``n`` new engine artifacts.

    Yields a dict filled on exit with ``before``/``after`` stats and the
    ``misses``/``hits`` deltas. Raises ``AssertionError`` on mismatch,
    naming every engine key that missed inside the block so the forking
    field is readable straight off the diff::

        with lint.expect_compiles(1):
            run(spec)            # first call: one build
        with lint.expect_compiles(0):
            run(spec)            # identical spec: pure cache hits
    """
    from repro import obs
    before = obs.cache_stats()
    info: dict = {"before": before}
    yield info
    after = obs.cache_stats()
    info["after"] = after
    info["misses"] = after["misses"] - before["misses"]
    info["hits"] = after["hits"] - before["hits"]
    ok = info["misses"] == n if exact else info["misses"] <= n
    if not ok:
        prev = {k: s["misses"] for k, s in before["engines"].items()}
        fresh = [k for k, s in after["engines"].items()
                 if s["misses"] > prev.get(k, 0)]
        raise AssertionError(
            f"expected {'exactly' if exact else 'at most'} {n} engine "
            f"compile(s), saw {info['misses']} "
            f"(hits {info['hits']}); keys that missed: {fresh or 'none'}")


def trace_count(spec, *, shard: bool = False, faulted: bool = False,
                fault_axis: bool = False) -> Optional[int]:
    """How many programs jax has traced for this spec's live artifact.

    Reaches through the dispatch instrumentation (``__wrapped__``) to the
    underlying ``jax.jit`` wrapper and reads its cache size. A healthy
    engine reports 1 after any number of identical ``run()`` calls; 2+
    means an *intra-key* retrace the repo accounting cannot see (donated
    buffer reuse, weak-type promotion, an unstable static arg). Returns
    ``None`` when jax does not expose a cache-size probe (the caller
    should skip, not fail: absence of the probe is not absence of the
    bug).
    """
    from repro.core import experiment
    fn = experiment.compiled_engine(spec, shard=shard, faulted=faulted,
                                    fault_axis=fault_axis)
    fn = getattr(fn, "__wrapped__", fn)
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    return int(probe())
