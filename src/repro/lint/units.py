"""Units-of-measure abstract interpretation over the simulator core.

The paper's objective mixes $/kWh prices, kg CO₂/kWh grid intensity, W of
IT+CRAC power, GB payloads, tokens and tasks/h — and three of the repo's
real bugs (PR 3) were nothing but a scale factor applied, or dropped, in
the wrong place. This checker makes the unit/dimension bug class a lint
failure: it propagates units through the arithmetic of the core modules
(``dcsim/env.py``, ``latency.py``, ``capability.py``, ``power.py``,
``colocation.py``, ``renewables.py``, ``faults/failover.py``,
``faults/trace.py``, ``launch/roofline.py``) with a small intra-function
dataflow pass and flags:

- unit-inconsistent ``+``/``-``/comparisons (e.g. ``$/kWh + kg/kWh``);
- bare magic scale factors (``/ 1000.0``, ``* 1e9``) that are not one of
  the declared conversion constants in ``repro.units``;
- emitted-metric suffix contracts: every dict key / subscript store ending
  ``_usd``/``_kg``/``_ms``/``_w`` must carry that unit;
- calls whose arguments contradict the declared parameter units, and
  returns that contradict the declared return unit.

Units are declared exactly once, in three places the checker machine-reads:

1. **Class docstring unit tables** — ``EnvParams``, ``CapabilityBundle``,
   ``FaultTrace``, ``AccelType``, ``ServingProfile`` each carry a
   ``Machine-read unit table (repro.lint.units):`` block of
   ``field: unit`` lines. The table must list exactly the class's fields
   in order — doc drift is itself a lint failure.
2. **Conversion-constant pragmas** — ``W_PER_KW = 1000.0  # lint:
   unit(W/kW)`` declares the constant's unit (and sanctions its
   magnitude); see ``repro.units``.
3. **The SIGNATURES table below** — parameter/return units of the core
   functions, so units flow across calls without whole-program inference.

Unit grammar: ``atom ('*' atom)* ('/' atom)*`` over the atoms in
``ATOMS`` (``USD``, ``W``, ``kW``, ``kgCO2``, ``GB``, ``GiB``, ``B``,
``token``, ``task``, ``chip``, ``node``, ``ms``, ``s``, ``h``, ``month``,
``km``, ``degC``, ``FLOP``), with ``1`` for dimensionless and the
compounds ``kWh`` ≡ ``kW*h`` and ``J`` ≡ ``W*s``. ``W`` and ``kW`` are
*distinct* atoms related only through ``W_PER_KW`` — a dropped ``/1000``
is a dimensional mismatch, not a silent factor.

Abstract domain: a known ``Unit``; ``ANY`` (unknown — unifies with
everything, so the checker only fires where both sides are known); and
numeric literals, which are dimensionless under ``*``/``/`` but wildcards
under ``+``/``-``/comparison (``er * 3600.0`` keeps er's unit;
``jnp.maximum(x, 1e-9)`` never false-positives). Escapes use
``# lint: unit-ok(reason)`` on the offending line, stale-checked like
every pragma.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple, Union

from .project import Project, Violation
from .purity import Graph

# ---------------------------------------------------------------------------
# the unit algebra
# ---------------------------------------------------------------------------

#: base dimensions. W/kW (and B/GB/GiB, ms/s/h) are deliberately distinct
#: atoms: conversions must go through the named constants in repro.units.
ATOMS = frozenset({
    "USD", "W", "kW", "kgCO2", "GB", "GiB", "B", "token", "task", "chip",
    "node", "ms", "s", "h", "month", "km", "degC", "FLOP",
})

#: compound spellings that expand into products of atoms
COMPOUND = {"kWh": (("kW", 1), ("h", 1)), "J": (("W", 1), ("s", 1))}


class Unit:
    """An immutable map atom -> integer exponent; {} is dimensionless."""

    __slots__ = ("exps",)

    def __init__(self, exps: Dict[str, int]):
        self.exps: Tuple[Tuple[str, int], ...] = tuple(
            sorted((a, e) for a, e in exps.items() if e != 0))

    def __eq__(self, other):
        return isinstance(other, Unit) and self.exps == other.exps

    def __hash__(self):
        return hash(self.exps)

    def __mul__(self, other: "Unit") -> "Unit":
        d = dict(self.exps)
        for a, e in other.exps:
            d[a] = d.get(a, 0) + e
        return Unit(d)

    def __truediv__(self, other: "Unit") -> "Unit":
        d = dict(self.exps)
        for a, e in other.exps:
            d[a] = d.get(a, 0) - e
        return Unit(d)

    def __pow__(self, n: int) -> "Unit":
        return Unit({a: e * n for a, e in self.exps})

    @property
    def dimensionless(self) -> bool:
        return not self.exps

    def __repr__(self) -> str:
        if not self.exps:
            return "1"
        num = [a if e == 1 else f"{a}^{e}" for a, e in self.exps if e > 0]
        den = [a if e == -1 else f"{a}^{-e}" for a, e in self.exps if e < 0]
        s = "*".join(num) if num else "1"
        for a in den:
            s += "/" + a
        return s


DIMENSIONLESS = Unit({})


class _Any:
    """Unknown unit: unifies with everything, absorbs products."""

    def __repr__(self):
        return "?"


ANY = _Any()


class Literal:
    """A numeric literal: dimensionless under * and /, a wildcard under
    +, -, comparison and unification. ``value`` is the folded float when
    statically known (for the magic-factor and positivity checks)."""

    __slots__ = ("value",)

    def __init__(self, value: Optional[float] = None):
        self.value = value

    def __repr__(self):
        return f"lit({self.value})"


class ClassVal:
    """An instance of a unit-table class: attribute access yields the
    declared field unit."""

    __slots__ = ("cls",)

    def __init__(self, cls: str):
        self.cls = cls

    def __repr__(self):
        return f"<{self.cls}>"


AbstractVal = Union[Unit, _Any, Literal, ClassVal]


def parse_unit(text: str) -> Unit:
    """``atom ('*' atom)* ('/' atom)*`` -> Unit. Raises ValueError on an
    unknown atom (a typo'd declaration must fail loudly)."""
    text = text.strip()
    if text in ("1", ""):
        return DIMENSIONLESS
    out: Dict[str, int] = {}

    def add(atom: str, sign: int) -> None:
        atom = atom.strip()
        if atom == "1":
            return
        if atom in COMPOUND:
            for a, e in COMPOUND[atom]:
                out[a] = out.get(a, 0) + sign * e
            return
        if atom not in ATOMS:
            raise ValueError(
                f"unknown unit atom {atom!r} (known: "
                f"{', '.join(sorted(ATOMS | set(COMPOUND)))}, 1)")
        out[atom] = out.get(atom, 0) + sign

    parts = text.split("/")
    for a in parts[0].split("*"):
        add(a, +1)
    for p in parts[1:]:
        for a in p.split("*"):
            add(a, -1)
    return Unit(out)


def parse_unit_decl(text: str) -> Tuple[AbstractVal, ...]:
    """A declaration: one unit, ``@ClassName``, ``-`` (no unit), or a
    comma list of those (tuple returns). ``A|B`` alternation is handled
    by the caller (return checks only)."""
    out: List[AbstractVal] = []
    for part in text.split(","):
        part = part.strip()
        if part == "-":
            out.append(ANY)
        elif part.startswith("@"):
            out.append(ClassVal(part[1:]))
        else:
            out.append(parse_unit(part))
    return tuple(out)


# ---------------------------------------------------------------------------
# declared knowledge: classes, signatures, metric suffixes
# ---------------------------------------------------------------------------

#: class name -> defining module. Each class docstring carries the
#: machine-read ``field: unit`` table this checker parses (and checks
#: against the AST field list, so the docs cannot drift).
UNIT_CLASSES: Dict[str, str] = {
    "EnvParams": "repro.dcsim.env",
    "CapabilityBundle": "repro.dcsim.capability",
    "FaultTrace": "repro.faults.trace",
    "AccelType": "repro.dcsim.topology",
    "ServingProfile": "repro.dcsim.capability",
}

UNIT_TABLE_MARKER = "Machine-read unit table"

#: (module, function) -> {param: decl, "return": decl}. ``@Class`` marks
#: a unit-table class; ``-`` opts a param out; ``A | B`` on a return is
#: an alternation (any branch may return either).
SIGNATURES: Dict[Tuple[str, str], Dict[str, str]] = {
    # -- dcsim.env ----------------------------------------------------------
    ("repro.dcsim.env", "capacity_at"): {"env": "@EnvParams", "return": "task/h"},
    ("repro.dcsim.env", "origin_at"): {"env": "@EnvParams", "return": "1"},
    ("repro.dcsim.env", "source_rtt"): {"env": "@EnvParams", "return": "ms"},
    ("repro.dcsim.env", "aggregate_origin"): {"env": "@EnvParams", "return": "@EnvParams"},
    ("repro.dcsim.env", "crac_cap_t"): {"env": "@EnvParams", "return": "W"},
    ("repro.dcsim.env", "dp_max_t"): {"env": "@EnvParams", "return": "W"},
    ("repro.dcsim.env", "power_cop"): {"env": "@EnvParams", "return": "1"},
    ("repro.dcsim.env", "load_share"): {"env": "@EnvParams", "ar": "task/h", "return": "1"},
    ("repro.dcsim.env", "dp_est"): {"env": "@EnvParams", "ar": "task/h", "return": "W"},
    ("repro.dcsim.env", "cet_est"): {"env": "@EnvParams", "ar": "task/h", "return": "kgCO2/h"},
    ("repro.dcsim.env", "ce_est"): {"env": "@EnvParams", "ar": "task/h", "return": "kgCO2/h"},
    ("repro.dcsim.env", "nc_est"): {"env": "@EnvParams", "ar": "task/h", "return": "USD/h"},
    ("repro.dcsim.env", "grid_power"): {"env": "@EnvParams", "ar": "task/h", "return": "W"},
    ("repro.dcsim.env", "peak_increase"): {
        "env": "@EnvParams", "ar": "task/h", "peak_state": "W", "return": "USD, W"},
    ("repro.dcsim.env", "cct_est"): {
        "env": "@EnvParams", "ar": "task/h", "peak_state": "W", "return": "USD/h"},
    ("repro.dcsim.env", "cc_est"): {
        "env": "@EnvParams", "ar": "task/h", "peak_state": "W", "return": "USD/h"},
    ("repro.dcsim.env", "latency_ms"): {"env": "@EnvParams", "ar": "task/h", "return": "ms"},
    ("repro.dcsim.env", "sla_cost"): {
        "env": "@EnvParams", "ar": "task/h", "lat_ms": "ms", "return": "USD/h"},
    ("repro.dcsim.env", "sla_cost_est"): {"env": "@EnvParams", "ar": "task/h", "return": "USD/h"},
    ("repro.dcsim.env", "latency_ms_routed"): {
        "env": "@EnvParams", "ar": "task/h", "return": "ms"},
    ("repro.dcsim.env", "sla_cost_routed"): {
        "env": "@EnvParams", "ar3": "task/h", "lat_ms": "ms", "return": "USD/h"},
    ("repro.dcsim.env", "sla_cost_est_routed"): {
        "env": "@EnvParams", "ar3": "task/h", "return": "USD/h"},
    ("repro.dcsim.env", "player_reward"): {
        "env": "@EnvParams", "ar": "task/h", "peak_state": "W",
        "return": "kgCO2/h | USD/h"},
    ("repro.dcsim.env", "feasible_violation"): {
        "env": "@EnvParams", "ar": "task/h", "return": "task/h"},
    ("repro.dcsim.env", "project_feasible"): {
        "env": "@EnvParams", "fractions": "1", "return": "task/h"},
    ("repro.dcsim.env", "project_feasible_routed"): {
        "env": "@EnvParams", "fractions": "1", "return": "task/h"},
    ("repro.dcsim.env", "step_epoch"): {
        "env": "@EnvParams", "ar": "task/h", "peak_state": "W", "return": "W, -"},
    # -- dcsim.latency ------------------------------------------------------
    ("repro.dcsim.latency", "haversine_km"): {"return": "km"},
    ("repro.dcsim.latency", "rtt_matrix"): {"return": "ms"},
    ("repro.dcsim.latency", "access_ms"): {"rtt": "ms", "return": "ms"},
    ("repro.dcsim.latency", "service_ms"): {
        "er": "task/h", "nn_total": "node", "return": "ms"},
    ("repro.dcsim.latency", "queue_factor"): {"rho": "1", "return": "1"},
    ("repro.dcsim.latency", "expected_latency_ms"): {
        "er": "task/h", "nn_total": "node", "rho": "1", "rtt": "ms",
        "return": "ms"},
    ("repro.dcsim.latency", "expected_latency_ms_routed"): {
        "er": "task/h", "nn_total": "node", "rho": "1", "src_rtt": "ms",
        "return": "ms"},
    ("repro.dcsim.latency", "sla_miss_prob"): {
        "lat_ms": "ms", "sla_ms": "ms", "return": "1"},
    ("repro.dcsim.latency", "default_sla_ms"): {
        "er": "task/h", "nn_total": "node", "margin": "1", "return": "ms"},
    # -- dcsim.power / colocation / renewables ------------------------------
    ("repro.dcsim.power", "cop"): {"t_supply_c": "degC", "return": "1"},
    ("repro.dcsim.power", "node_power_arrays"): {"return": "W, W"},
    ("repro.dcsim.power", "compute_power"): {"rho": "1", "return": "W"},
    ("repro.dcsim.power", "crac_power"): {
        "it_power_w": "W", "t_supply_c": "degC", "return": "W"},
    ("repro.dcsim.power", "dp_max"): {
        "eff": "1", "t_supply_c": "degC", "rp_w": "W", "return": "W"},
    ("repro.dcsim.colocation", "base_time_table"): {"return": "s"},
    ("repro.dcsim.colocation", "coer_core"): {"return": "task/s"},
    ("repro.dcsim.colocation", "er_table"): {"return": "task/h"},
    ("repro.dcsim.renewables", "renewable_profile"): {
        "installed_w": "W", "return": "W"},
    # -- faults -------------------------------------------------------------
    ("repro.faults.failover", "realized_env"): {
        "env": "@EnvParams", "trace": "@FaultTrace", "return": "@EnvParams"},
    ("repro.faults.failover", "_nearness"): {
        "renv": "@EnvParams", "return": "1"},
    ("repro.faults.failover", "_redistribute"): {
        "kept": "task/h", "over": "task/h", "cap": "task/h", "kern": "1",
        "return": "task/h"},
    ("repro.faults.failover", "apply_failover"): {
        "renv": "@EnvParams", "ar": "task/h",
        "return": "task/h, task/h, task/h"},
    ("repro.faults.failover", "execute_hour"): {
        "env": "@EnvParams", "trace": "@FaultTrace", "peak_state": "W",
        "ar": "task/h", "return": "W, -"},
    # -- launch.roofline ----------------------------------------------------
    ("repro.launch.roofline", "_shape_bytes"): {"return": "B"},
}

#: metric-name suffix -> admissible units. Rates and their one-epoch
#: totals are both admitted: the engines sum per-hour values over a day,
#: and the epoch is exactly 1 h (documented in dcsim.env).
SUFFIX_UNITS: Dict[str, Tuple[Unit, ...]] = {
    "_usd": (parse_unit("USD"), parse_unit("USD/h")),
    "_kg": (parse_unit("kgCO2"), parse_unit("kgCO2/h")),
    "_ms": (parse_unit("ms"),),
    "_w": (parse_unit("W"),),
}

#: modules whose function bodies the dataflow pass interprets (and whose
#: arithmetic the magic-factor check polices)
UNIT_MODULES: Tuple[str, ...] = (
    "repro.units",
    "repro.dcsim.env",
    "repro.dcsim.latency",
    "repro.dcsim.capability",
    "repro.dcsim.power",
    "repro.dcsim.colocation",
    "repro.dcsim.renewables",
    "repro.faults.failover",
    "repro.faults.trace",
    "repro.launch.roofline",
)

#: |constant| at or above this, multiplying or dividing, is a scale
#: factor that must be a named, unit-declared conversion constant
MAGIC_THRESHOLD = 1000.0

# jnp/np call semantics by terminal function name ---------------------------

_PASSTHROUGH = {
    "asarray", "array", "float32", "float64", "abs", "absolute", "sum",
    "mean", "max", "min", "amax", "amin", "nansum", "nanmean", "squeeze",
    "reshape", "transpose", "ravel", "broadcast_to", "tile", "sort",
    "cumsum", "diag", "real", "nan_to_num", "stop_gradient", "flip",
    "roll", "atleast_1d", "atleast_2d", "stack", "concatenate", "copy",
    "ascontiguousarray",
}
_UNIFY = {"maximum", "minimum", "clip", "fmax", "fmin", "hypot", "mod",
          "remainder"}
_DIMLESS = {
    "sigmoid", "exp", "log", "log1p", "expm1", "tanh", "softmax", "cos",
    "sin", "tan", "arcsin", "arccos", "arctan", "arctan2", "sign",
    "isnan", "isfinite", "isinf", "radians", "degrees", "logical_and",
    "logical_or", "logical_not",
}
_LITERAL_MAKERS = {"zeros", "ones", "full", "zeros_like", "ones_like",
                   "full_like", "eye", "arange", "linspace"}
_PRODUCT = {"dot", "matmul", "outer", "multiply"}
_METHOD_PASSTHROUGH = {"sum", "mean", "max", "min", "reshape", "astype",
                       "transpose", "clip", "squeeze", "ravel", "copy",
                       "flatten", "cumsum"}


def _const_fold(node: ast.AST) -> Optional[float]:
    """Fold a numeric-literal expression (constants, ``-x``, ``a ** b``,
    ``a * b``, ``a / b``) to its float value, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)) \
            and not isinstance(node.value, bool):
        return float(node.value)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        v = _const_fold(node.operand)
        if v is None:
            return None
        return -v if isinstance(node.op, ast.USub) else v
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.Pow, ast.Mult, ast.Div)):
        a, b = _const_fold(node.left), _const_fold(node.right)
        if a is None or b is None:
            return None
        try:
            if isinstance(node.op, ast.Pow):
                return float(a ** b)
            if isinstance(node.op, ast.Mult):
                return a * b
            return a / b
        except (OverflowError, ZeroDivisionError):
            return None
    return None


# ---------------------------------------------------------------------------
# declaration harvesting: class docstring tables + unit(...) constants
# ---------------------------------------------------------------------------

class UnitWorld:
    """Everything the interpreter knows before touching any function body:
    per-class field units, per-constant units/values, and the violations
    harvesting itself produced (bad atoms, table drift)."""

    def __init__(self, project: Project, graph: Graph):
        self.project = project
        self.graph = graph
        #: class name -> {field: AbstractVal}
        self.class_fields: Dict[str, Dict[str, AbstractVal]] = {}
        #: dotted "module.NAME" -> (AbstractVal, folded value or None)
        self.constants: Dict[str, Tuple[AbstractVal, Optional[float]]] = {}
        self.violations: List[Violation] = []
        self._harvest_classes()
        self._harvest_constants()

    # -- class docstring unit tables ---------------------------------------

    def _harvest_classes(self) -> None:
        for cls, module in UNIT_CLASSES.items():
            sf = self.project.module(module)
            if sf is None or sf.tree is None:
                continue
            node = next((n for n in sf.tree.body
                         if isinstance(n, ast.ClassDef) and n.name == cls),
                        None)
            if node is None:
                self.violations.append(Violation(
                    sf.relpath, 1, "units",
                    f"unit-table class `{cls}` not found in {module} — "
                    "update UNIT_CLASSES or restore the class"))
                continue
            self.class_fields[cls] = self._parse_class(sf.relpath, node)

    def _parse_class(self, rel: str, node: ast.ClassDef) -> Dict[str, AbstractVal]:
        fields = [s.target.id for s in node.body
                  if isinstance(s, ast.AnnAssign)
                  and isinstance(s.target, ast.Name)]
        doc = ast.get_docstring(node) or ""
        table: Dict[str, AbstractVal] = {}
        lines = doc.splitlines()
        start = next((i for i, ln in enumerate(lines)
                      if UNIT_TABLE_MARKER in ln), None)
        if start is None:
            self.violations.append(Violation(
                rel, node.lineno, "units",
                f"class `{node.name}` has no '{UNIT_TABLE_MARKER}' block in "
                "its docstring — every unit-table class declares its field "
                "units there (see EnvParams)"))
            return {f: ANY for f in fields}
        order: List[str] = []
        for ln in lines[start + 1:]:
            ln = ln.strip()
            if not ln:
                continue
            if ":" not in ln:
                break
            name, _, unit_text = ln.partition(":")
            name = name.strip()
            if not name.isidentifier():
                break
            try:
                table[name] = parse_unit_decl(unit_text)[0]
            except ValueError as e:
                self.violations.append(Violation(
                    rel, node.lineno, "units",
                    f"`{node.name}.{name}` unit declaration: {e}"))
                table[name] = ANY
            order.append(name)
        if order != fields:
            missing = [f for f in fields if f not in order]
            extra = [f for f in order if f not in fields]
            self.violations.append(Violation(
                rel, node.lineno, "units",
                f"`{node.name}` unit table drifted from the field list: "
                f"missing {missing or '[]'}, stray {extra or '[]'} "
                "(the docstring table is the machine-read source of truth "
                "— keep it exactly in field order)"))
        for f in fields:
            table.setdefault(f, ANY)
        return table

    # -- unit(...) pragma constants ----------------------------------------

    def _harvest_constants(self) -> None:
        for rel, sf in self.project.sources.items():
            if sf.tree is None or sf.module is None:
                continue
            for node in sf.tree.body:
                if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    continue
                pragma = self.project.pragma_at(rel, node.lineno, "unit")
                if pragma is None:
                    continue
                self.project.use_pragma(rel, node.lineno)
                name = node.targets[0].id
                try:
                    unit = parse_unit(pragma.reason)
                except ValueError as e:
                    self.violations.append(Violation(
                        rel, node.lineno, "units",
                        f"constant `{name}` unit declaration: {e}"))
                    continue
                self.constants[f"{sf.module}.{name}"] = (
                    unit, _const_fold(node.value))

    # -- lookups ------------------------------------------------------------

    def field_unit(self, cls: str, field: str) -> AbstractVal:
        return self.class_fields.get(cls, {}).get(field, ANY)

    def constant(self, dotted: str) -> Optional[Tuple[AbstractVal, Optional[float]]]:
        return self.constants.get(dotted)

    def signature(self, module: str, name: str) -> Optional[Dict[str, str]]:
        sig = SIGNATURES.get((module, name))
        if sig is not None:
            return sig
        tgt = self.graph.resolve_symbol(module, name)
        if tgt is not None:
            return SIGNATURES.get(tgt)
        return None


# ---------------------------------------------------------------------------
# the intra-function dataflow interpreter
# ---------------------------------------------------------------------------

def _unify(a: AbstractVal, b: AbstractVal) -> Tuple[AbstractVal, bool]:
    """Join two values as +/-/comparison/where does. Returns (result,
    mismatch): mismatch only when both are *known* units that differ."""
    if isinstance(a, Unit) and isinstance(b, Unit):
        if a == b:
            return a, False
        return a, True
    if isinstance(a, Unit):
        return a, False
    if isinstance(b, Unit):
        return b, False
    if isinstance(a, Literal) and isinstance(b, Literal):
        return Literal(), False
    return ANY, False


def _mul(a: AbstractVal, b: AbstractVal) -> AbstractVal:
    if isinstance(a, Literal):
        return b if not isinstance(b, Literal) else Literal()
    if isinstance(b, Literal):
        return a
    if isinstance(a, Unit) and isinstance(b, Unit):
        return a * b
    return ANY


def _div(a: AbstractVal, b: AbstractVal) -> AbstractVal:
    if isinstance(b, Literal):
        return a if not isinstance(a, Literal) else Literal()
    if isinstance(a, Literal):
        if isinstance(b, Unit):
            return DIMENSIONLESS / b
        return ANY
    if isinstance(a, Unit) and isinstance(b, Unit):
        return a / b
    return ANY


class FunctionScan:
    """Abstract-interpret one top-level function (plus its nested defs):
    propagate units through assignments, flag mismatches, check declared
    signatures, suffix contracts and constructor keywords."""

    def __init__(self, world: UnitWorld, module: str, qualname: str,
                 fn: ast.AST):
        self.world = world
        self.graph = world.graph
        self.table = world.graph.tables[module]
        self.module = module
        self.qualname = qualname
        self.fn = fn
        self.findings: List[Tuple[int, str]] = []
        self.env: Dict[str, AbstractVal] = {}
        self.return_decl = self._decl_of(fn)
        self._bind_params(fn)
        self._exec_body(fn.body)

    # -- declarations -------------------------------------------------------

    def _decl_of(self, fn: ast.AST) -> Optional[str]:
        sig = SIGNATURES.get((self.module, self.qualname))
        return sig.get("return") if sig else None

    def _bind_params(self, fn: ast.AST) -> None:
        sig = SIGNATURES.get((self.module, self.qualname), {})
        a = fn.args
        params = a.posonlyargs + a.args + a.kwonlyargs
        for arg in params:
            val: AbstractVal = ANY
            if arg.arg in sig:
                try:
                    val = parse_unit_decl(sig[arg.arg])[0]
                except ValueError:
                    val = ANY
            elif arg.annotation is not None:
                val = self._class_from_annotation(arg.annotation)
            self.env[arg.arg] = val
        if a.vararg:
            self.env[a.vararg.arg] = ANY
        if a.kwarg:
            self.env[a.kwarg.arg] = ANY

    def _class_from_annotation(self, ann: ast.AST) -> AbstractVal:
        """``env: EnvParams`` / ``env: E.EnvParams`` / ``acc:
        "topology.AccelType"`` -> ClassVal."""
        text = None
        if isinstance(ann, ast.Name):
            text = ann.id
        elif isinstance(ann, ast.Attribute):
            text = ann.attr
        elif isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            text = ann.value.rsplit(".", 1)[-1].strip()
        if text in UNIT_CLASSES:
            return ClassVal(text)
        return ANY

    # -- statement execution ------------------------------------------------

    def _exec_body(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self._exec(stmt)

    def _exec(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            val = self._eval(stmt.value)
            for t in stmt.targets:
                self._assign(t, val, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._assign(stmt.target, self._eval(stmt.value), stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            cur = (self.env.get(stmt.target.id, ANY)
                   if isinstance(stmt.target, ast.Name) else ANY)
            val = self._binop_val(stmt.op, cur, self._eval(stmt.value),
                                  stmt.lineno)
            self._assign(stmt.target, val, stmt.value)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._check_return(stmt)
        elif isinstance(stmt, (ast.If, ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                self._assign(stmt.target, ANY, None)
                self._eval(stmt.iter)
            else:
                self._eval(stmt.test if isinstance(stmt, (ast.If, ast.While))
                           else stmt)
            self._exec_body(stmt.body)
            self._exec_body(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._eval(item.context_expr)
                if item.optional_vars is not None:
                    self._assign(item.optional_vars, ANY, None)
            self._exec_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._exec_body(stmt.body)
            for h in stmt.handlers:
                self._exec_body(h.body)
            self._exec_body(stmt.orelse)
            self._exec_body(stmt.finalbody)
        elif isinstance(stmt, ast.Expr):
            self._eval(stmt.value)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (scan bodies, closures) are interpreted in the
            # enclosing frame: closure names keep their units, params ANY
            saved = dict(self.env)
            for arg in (stmt.args.posonlyargs + stmt.args.args
                        + stmt.args.kwonlyargs):
                self.env[arg.arg] = ANY
            self._exec_body(stmt.body)
            self.env = saved
            self.env[stmt.name] = ANY
        # pass/raise/assert/import/global/delete: nothing to propagate

    def _assign(self, target: ast.AST, val: AbstractVal,
                value_node: Optional[ast.AST]) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            parts: Optional[Tuple[AbstractVal, ...]] = None
            if isinstance(value_node, ast.Call):
                decl = self._call_return_decl(value_node)
                if decl is not None and "," in decl:
                    try:
                        parts = parse_unit_decl(decl)
                    except ValueError:
                        parts = None
            if parts is None and isinstance(value_node, (ast.Tuple, ast.List)):
                parts = tuple(self._eval(e) for e in value_node.elts)
            for i, t in enumerate(target.elts):
                self._assign(t, parts[i] if parts and i < len(parts) else ANY,
                             None)
        elif isinstance(target, ast.Subscript):
            # m["..."] = expr: the emitted-metric suffix contract
            self._check_suffix_store(target, val)
        elif isinstance(target, ast.Starred):
            self._assign(target.value, ANY, None)
        # attribute stores: nothing tracked

    def _check_return(self, stmt: ast.Return) -> None:
        decl = self.return_decl
        val_node = stmt.value
        if decl is None:
            self._eval(val_node)
            return
        # tuple returns against "A, B" declarations, element-wise
        decls = [d.strip() for d in decl.split(",")]
        if isinstance(val_node, ast.Tuple) and len(decls) == len(val_node.elts):
            for d, e in zip(decls, val_node.elts):
                self._check_one_return(d, self._eval(e), stmt.lineno)
            return
        self._check_one_return(decl, self._eval(val_node), stmt.lineno)

    def _check_one_return(self, decl: str, got: AbstractVal,
                          line: int) -> None:
        if not isinstance(got, Unit):
            return
        alts = []
        for alt in decl.split("|"):
            alt = alt.strip()
            if alt in ("-",) or alt.startswith("@") or "," in alt:
                return
            try:
                alts.append(parse_unit(alt))
            except ValueError:
                return
        if got not in alts:
            want = " | ".join(repr(a) for a in alts)
            self.findings.append((line, (
                f"`{self.qualname}` returns {got!r} but is declared to "
                f"return {want} (SIGNATURES in repro.lint.units)")))

    def _check_suffix_store(self, target: ast.Subscript,
                            val: AbstractVal) -> None:
        key = target.slice
        if not (isinstance(key, ast.Constant) and isinstance(key.value, str)):
            return
        self._check_suffix(key.value, val, target.lineno)

    def _check_suffix(self, key: str, val: AbstractVal, line: int) -> None:
        if not isinstance(val, Unit):
            return
        for suffix, allowed in SUFFIX_UNITS.items():
            if key.endswith(suffix):
                if val not in allowed:
                    want = " or ".join(repr(u) for u in allowed)
                    self.findings.append((line, (
                        f"metric `{key}` carries {val!r}, but the "
                        f"`{suffix}` suffix contract requires {want}")))
                return

    # -- expression evaluation ---------------------------------------------

    def _eval(self, node: Optional[ast.AST]) -> AbstractVal:
        if node is None:
            return ANY
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)) \
                    and not isinstance(node.value, bool):
                return Literal(float(node.value))
            return ANY
        if isinstance(node, ast.Name):
            return self._name(node.id)
        if isinstance(node, ast.Attribute):
            return self._attribute(node)
        if isinstance(node, ast.BinOp):
            return self._binop(node)
        if isinstance(node, ast.UnaryOp):
            v = self._eval(node.operand)
            if isinstance(node.op, (ast.USub, ast.UAdd)) \
                    and isinstance(v, Literal) and v.value is not None:
                return Literal(-v.value if isinstance(node.op, ast.USub)
                               else v.value)
            return v
        if isinstance(node, ast.BoolOp):
            for e in node.values:
                self._eval(e)
            return ANY
        if isinstance(node, ast.Compare):
            self._compare(node)
            return ANY
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.Subscript):
            self._eval(node.slice)
            return self._eval(node.value)   # indexing preserves the unit
        if isinstance(node, ast.IfExp):
            self._eval(node.test)
            out, _ = _unify(self._eval(node.body), self._eval(node.orelse))
            return out
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values):
                val = self._eval(v)
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    self._check_suffix(k.value, val, k.lineno)
            return ANY
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            for e in node.elts:
                self._eval(e)
            return ANY
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self._comprehension(node)
        if isinstance(node, ast.DictComp):
            saved = dict(self.env)
            self._bind_comp_targets(node.generators)
            self._eval(node.key)
            self._eval(node.value)
            self.env = saved
            return ANY
        if isinstance(node, ast.Lambda):
            return ANY
        if isinstance(node, ast.Starred):
            return self._eval(node.value)
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue, ast.Slice)):
            return ANY
        if isinstance(node, ast.NamedExpr):
            val = self._eval(node.value)
            self._assign(node.target, val, node.value)
            return val
        return ANY

    def _bind_comp_targets(self, generators) -> None:
        for gen in generators:
            self._eval(gen.iter)
            self._assign(gen.target, ANY, None)
            for cond in gen.ifs:
                self._eval(cond)

    def _comprehension(self, node) -> AbstractVal:
        """A list/gen comprehension evaluates to its element's unit (the
        ``np.array([...])`` construction idiom keeps its unit)."""
        saved = dict(self.env)
        self._bind_comp_targets(node.generators)
        elt = self._eval(node.elt)
        self.env = saved
        return elt if isinstance(elt, (Unit, Literal)) else ANY

    def _name(self, name: str) -> AbstractVal:
        if name in self.env:
            return self.env[name]
        hit = self.world.constant(f"{self.module}.{name}")
        if hit is not None:
            return hit[0]
        if name in self.table.import_objects:
            mod, orig = self.table.import_objects[name]
            hit = self.world.constant(f"{mod}.{orig}")
            if hit is not None:
                return hit[0]
        return ANY

    def _attribute(self, node: ast.Attribute) -> AbstractVal:
        base = self._eval(node.value)
        if isinstance(base, ClassVal):
            if node.attr in self.world.class_fields.get(base.cls, {}):
                return self.world.field_unit(base.cls, node.attr)
            return ANY
        # module-constant access through an import alias (R.PEAK_FLOPS,
        # units.W_PER_KW, topology.NETWORK_PRICE)
        dotted = self.graph.dotted_of(self.table.import_modules,
                                      self.table.import_objects, node,
                                      set(self.env))
        if dotted is not None:
            hit = self.world.constant(dotted)
            if hit is not None:
                return hit[0]
        return ANY

    def _binop(self, node: ast.BinOp) -> AbstractVal:
        left = self._eval(node.left)
        right = self._eval(node.right)
        return self._binop_val(node.op, left, right, node.lineno,
                               node=node)

    def _binop_val(self, op: ast.operator, left: AbstractVal,
                   right: AbstractVal, line: int,
                   node: Optional[ast.BinOp] = None) -> AbstractVal:
        if isinstance(op, (ast.Mult, ast.MatMult)):
            return _mul(left, right)
        if isinstance(op, (ast.Div, ast.FloorDiv)):
            return _div(left, right)
        if isinstance(op, (ast.Add, ast.Sub)):
            out, mismatch = _unify(left, right)
            if mismatch:
                opname = "+" if isinstance(op, ast.Add) else "-"
                self.findings.append((line, (
                    f"unit mismatch: {left!r} {opname} {right!r} — operands "
                    "of addition/subtraction must share a unit (convert "
                    "through a repro.units constant, or mark the line "
                    "# lint: unit-ok(reason))")))
            return out
        if isinstance(op, ast.Pow):
            if isinstance(left, Unit) and node is not None:
                n = _const_fold(node.right)
                if n is not None and float(n).is_integer():
                    return left ** int(n)
                return ANY
            if isinstance(left, Literal) and node is not None:
                v = _const_fold(node)
                return Literal(v)
            return ANY
        if isinstance(op, ast.Mod):
            return left
        return ANY

    def _compare(self, node: ast.Compare) -> None:
        vals = [self._eval(node.left)] + [self._eval(c)
                                          for c in node.comparators]
        for op, a, b in zip(node.ops, vals, vals[1:]):
            if isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn)):
                continue
            _, mismatch = _unify(a, b)
            if mismatch:
                self.findings.append((node.lineno, (
                    f"unit mismatch: comparing {a!r} against {b!r} — both "
                    "sides of a comparison must share a unit")))

    # -- calls --------------------------------------------------------------

    def _call_target(self, node: ast.Call) -> Optional[Tuple[str, str]]:
        """Resolve a call to a (module, function) defined in the project."""
        func = node.func
        if isinstance(func, ast.Name):
            if func.id in self.env:
                return None
            if func.id in self.table.functions:
                return (self.module, func.id)
            return self.graph.resolve_symbol(self.module, func.id)
        if isinstance(func, ast.Attribute):
            dotted = self.graph.dotted_of(self.table.import_modules,
                                          self.table.import_objects, func,
                                          set(self.env))
            if dotted and dotted.startswith(("repro.", "examples.",
                                             "benchmarks.")):
                mod, _, name = dotted.rpartition(".")
                tgt = self.graph.resolve_symbol(mod, name)
                if tgt is not None:
                    return tgt
                if (mod, name) in SIGNATURES:
                    return (mod, name)
        return None

    def _call_return_decl(self, node: ast.Call) -> Optional[str]:
        tgt = self._call_target(node)
        if tgt is not None:
            sig = SIGNATURES.get(tgt)
            if sig is not None:
                return sig.get("return")
        return None

    def _terminal_name(self, func: ast.AST) -> Optional[str]:
        if isinstance(func, ast.Attribute):
            return func.attr
        if isinstance(func, ast.Name):
            return func.id
        return None

    def _call(self, node: ast.Call) -> AbstractVal:
        func = node.func
        argvals = [self._eval(a) for a in node.args]
        kwvals = {kw.arg: self._eval(kw.value) for kw in node.keywords
                  if kw.arg is not None}
        for kw in node.keywords:
            if kw.arg is None:
                self._eval(kw.value)

        # project-defined callee with a declared signature: check args,
        # trust the declared return
        tgt = self._call_target(node)
        if tgt is not None:
            sig = SIGNATURES.get(tgt)
            if sig is not None:
                self._check_sig_args(node, tgt, sig, argvals, kwvals)
                ret = sig.get("return")
                if ret is not None and "," not in ret and "|" not in ret:
                    try:
                        return parse_unit_decl(ret)[0]
                    except ValueError:
                        return ANY
                return ANY
            # constructor of a unit-table class?
            if tgt[1] in UNIT_CLASSES:
                self._check_ctor(node, tgt[1], kwvals)
                return ClassVal(tgt[1])
            return ANY

    # (continued below)
        # constructor called by bare name (classes are not in the function
        # table, so _call_target misses them): EnvParams(...), FaultTrace(...)
        if isinstance(func, ast.Name) and func.id in UNIT_CLASSES \
                and func.id not in self.env:
            self._check_ctor(node, func.id, kwvals)
            return ClassVal(func.id)
        if isinstance(func, ast.Attribute) and func.attr in UNIT_CLASSES:
            self._check_ctor(node, func.attr, kwvals)
            return ClassVal(func.attr)

        # ._replace(field=...) keeps the class and re-checks the fields
        if isinstance(func, ast.Attribute) and func.attr == "_replace":
            recv = self._eval(func.value)
            if isinstance(recv, ClassVal):
                self._check_ctor(node, recv.cls, kwvals)
                return recv
            return ANY

        name = self._terminal_name(func)
        if name is None:
            return ANY

        # x.sum() / x.astype(...) / x.clip(...): unit-preserving methods
        if isinstance(func, ast.Attribute) and name in _METHOD_PASSTHROUGH \
                and not isinstance(func.value, ast.Name) or (
                isinstance(func, ast.Attribute) and name in _METHOD_PASSTHROUGH
                and isinstance(func.value, ast.Name)
                and func.value.id in self.env):
            return self._eval(func.value)

        if name == "where":
            if len(argvals) >= 3:
                out, mismatch = _unify(argvals[1], argvals[2])
                if mismatch:
                    self.findings.append((node.lineno, (
                        f"unit mismatch: where(..) branches carry "
                        f"{argvals[1]!r} vs {argvals[2]!r}")))
                return out
            return ANY
        if name == "einsum":
            out: AbstractVal = Literal()
            for v in argvals[1:]:
                out = _mul(out, v)
            return out
        if name in _PRODUCT:
            if len(argvals) >= 2:
                return _mul(argvals[0], argvals[1])
            return argvals[0] if argvals else ANY
        if name in _UNIFY:
            vals = argvals + [v for k, v in kwvals.items()
                              if k in ("a_min", "a_max", "min", "max")]
            out = ANY
            mismatch_pair = None
            for v in vals:
                new, mismatch = _unify(out, v)
                if mismatch:
                    mismatch_pair = (out, v)
                out = new
            if mismatch_pair is not None:
                self.findings.append((node.lineno, (
                    f"unit mismatch: `{name}(..)` arguments carry "
                    f"{mismatch_pair[0]!r} vs {mismatch_pair[1]!r}")))
            return out
        if name in _PASSTHROUGH:
            return argvals[0] if argvals else ANY
        if name in _DIMLESS:
            for v in argvals:
                if isinstance(v, Unit) and not v.dimensionless:
                    self.findings.append((node.lineno, (
                        f"`{name}()` applied to a dimensioned quantity "
                        f"({v!r}): transcendental/logical functions take "
                        "dimensionless arguments — normalize first")))
            return DIMENSIONLESS
        if name in _LITERAL_MAKERS:
            return Literal()
        if name in ("max", "min") and isinstance(func, ast.Name):
            out = ANY
            for v in argvals:
                out, _ = _unify(out, v)
            return out
        if name in ("float", "int", "round") and isinstance(func, ast.Name):
            return argvals[0] if argvals else ANY
        return ANY

    def _check_sig_args(self, node: ast.Call, tgt: Tuple[str, str],
                        sig: Dict[str, str], argvals: List[AbstractVal],
                        kwvals: Dict[str, AbstractVal]) -> None:
        """Declared parameter units vs what the call site passes."""
        table = self.graph.tables.get(tgt[0])
        fn = table.functions.get(tgt[1]) if table else None
        if fn is None:
            return
        a = fn.args
        params = [p.arg for p in a.posonlyargs + a.args]
        named = dict(zip(params, argvals))
        named.update({k: v for k, v in kwvals.items() if k in sig})
        for pname, got in named.items():
            decl = sig.get(pname)
            if decl is None or not isinstance(got, Unit):
                continue
            try:
                want = parse_unit_decl(decl)[0]
            except ValueError:
                continue
            if isinstance(want, Unit) and got != want:
                self.findings.append((node.lineno, (
                    f"`{tgt[1]}(..., {pname}=...)` expects {want!r} but the "
                    f"call passes {got!r}")))

    def _check_ctor(self, node: ast.Call, cls: str,
                    kwvals: Dict[str, AbstractVal]) -> None:
        fields = self.world.class_fields.get(cls)
        if not fields:
            return
        for pname, got in kwvals.items():
            want = fields.get(pname)
            if isinstance(want, Unit) and isinstance(got, Unit) \
                    and got != want:
                self.findings.append((node.lineno, (
                    f"`{cls}({pname}=...)` expects {want!r} (declared in "
                    f"the class unit table) but the value carries {got!r}")))


# ---------------------------------------------------------------------------
# magic-factor scan
# ---------------------------------------------------------------------------

def _magic_scan(project: Project, module: str,
                out: List[Violation]) -> None:
    """Bare numeric factors >= MAGIC_THRESHOLD in a ``*``/``/`` are unit
    conversions in disguise — they must be a named constant from
    ``repro.units`` (declared with ``# lint: unit(...)``) or carry a
    reasoned ``unit-ok``/``unit`` pragma on the line."""
    sf = project.module(module)
    if sf is None or sf.tree is None:
        return
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.BinOp):
            continue
        if not isinstance(node.op, (ast.Mult, ast.Div, ast.FloorDiv)):
            continue
        for side in (node.left, node.right):
            v = _const_fold(side)
            if v is None or abs(v) < MAGIC_THRESHOLD:
                continue
            line = side.lineno
            for directive in ("unit", "unit-ok"):
                p = project.pragma_at(sf.relpath, line, directive)
                if p is not None:
                    project.use_pragma(sf.relpath, line)
                    break
            else:
                out.append(Violation(
                    sf.relpath, line, "units",
                    f"magic scale factor {v!r} in a multiplication/"
                    "division — unit conversions go through a named "
                    "constant in repro.units (declared with # lint: "
                    "unit(...)), so the dimensional analysis can see "
                    "them"))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def check(project: Project) -> List[Violation]:
    """Run the units checker over every module in UNIT_MODULES."""
    graph = Graph(project)
    world = UnitWorld(project, graph)
    out: List[Violation] = list(world.violations)
    for module in UNIT_MODULES:
        sf = project.module(module)
        if sf is None or sf.tree is None:
            continue
        _magic_scan(project, module, out)
        table = graph.tables.get(module)
        if table is None:
            continue
        for qualname, fn in sorted(table.functions.items()):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            scan = FunctionScan(world, module, qualname, fn)
            for line, msg in scan.findings:
                p = project.pragma_at(sf.relpath, line, "unit-ok")
                if p is not None:
                    project.use_pragma(sf.relpath, line)
                    continue
                out.append(Violation(sf.relpath, line, "units", msg))
    return out
