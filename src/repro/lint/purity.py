"""Trace-purity checker: host-side impurities reachable from jitted roots.

PRs 6–8 each hand-rediscovered the same bug class: code that runs *at trace
time* but depends on host state (a wall clock, a host RNG, a mutable module
global) silently bakes one trace's snapshot into a cached compiled artifact
— the program the cache replays is not the program the spec describes. This
checker makes that a lint failure instead of a code-review catch.

Mechanics: build a static call graph over the package's own source and walk
it from the *jitted roots* — the functions whose bodies become traced
programs:

- ``repro.core.experiment._day_core`` (the engine scan bodies, including
  the nested ``_body``/``day`` closures),
- every registered technique step (the six builtin ``solve_epoch``s, plus
  any function statically resolvable at a ``register_technique`` call
  site),
- the realized-fault execution path (``faults.failover.execute_hour``,
  ``faults.guard.guard_fractions``),
- the tap thunks (``game.tap_nash_residual``).

A *unit* is one top-level function or method together with everything
nested inside it (inner defs, lambdas, comprehensions) — closures passed to
``lax.scan``/``vmap`` are traced with their parent, so they are analyzed
with it too. Edges follow direct calls and bare references (callbacks) to
functions resolvable through this package's imports; external pure targets
(``jax.numpy`` etc.) terminate the walk.

Flagged inside reachable units:

==============================  ==========================================
pattern                         why it poisons a trace
==============================  ==========================================
``time.time``/``perf_counter``  wall-clock constant-folded into the trace
``np.random.*`` / ``random.*``  host RNG drawn once, frozen forever
``.item()`` / ``float()`` /     host sync on a traced value (or a silent
``int()`` / ``bool()``          trace-time constant-fold)
``jax.debug.callback`` & co.    host callback — legitimate ONLY at the
                                declared ``repro.obs`` escape hatches
module-global mutation          retrace-dependent behavior: the artifact
                                depends on *when* jit traced it
``print`` / ``open`` /``input`` host I/O from traced code
==============================  ==========================================

Deliberate exceptions carry ``# lint: host-ok(reason)`` on the offending
line (see ``project.Pragma``); the obs tap machinery's
``jax.debug.callback`` is the canonical one.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .project import Project, Violation

#: the declared jitted roots: (module, top-level function) pairs. Renaming
#: one without updating this list is itself a lint failure (a silently
#: missing root would un-check everything reachable from it).
TRACED_ROOTS: Tuple[Tuple[str, str], ...] = (
    ("repro.core.experiment", "_day_core"),
    ("repro.core.game", "tap_nash_residual"),
    ("repro.faults.failover", "execute_hour"),
    ("repro.faults.guard", "guard_fractions"),
    ("repro.core.force_directed", "solve_epoch"),
    ("repro.core.genetic", "solve_epoch"),
    ("repro.core.nash", "solve_epoch"),
    ("repro.core.ddpg", "solve_epoch"),
    ("repro.core.ppo_joint", "solve_epoch"),
    ("repro.core.gt_drl", "solve_epoch"),
)

_HOST_CLOCKS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "datetime.datetime.now",
    "datetime.datetime.utcnow", "datetime.date.today",
}

_HOST_CALLBACKS = {
    "jax.debug.callback", "jax.debug.print", "jax.pure_callback",
    "jax.experimental.io_callback", "jax.experimental.host_callback.call",
}

_HOST_IO = {"builtins.print", "builtins.open", "builtins.input"}

_MUTATORS = {"append", "extend", "add", "update", "pop", "popitem",
             "remove", "discard", "clear", "insert", "setdefault"}


def _impure_call(dotted: str) -> Optional[str]:
    """The violation message for a resolved dotted call name, or None."""
    if dotted in _HOST_CLOCKS:
        return (f"host clock `{dotted}` in traced code: the reading is "
                "constant-folded into the cached artifact at trace time")
    if dotted.startswith("numpy.random.") or dotted.startswith("random."):
        return (f"host RNG `{dotted}` in traced code: drawn once at trace "
                "time and frozen into every replay of the artifact")
    if dotted in _HOST_CALLBACKS:
        return (f"host callback `{dotted}` in traced code: only the "
                "declared repro.obs escape hatches may do this "
                "(# lint: host-ok(reason) if deliberate)")
    if dotted in _HOST_IO:
        return f"host I/O `{dotted}` in traced code"
    return None


# ---------------------------------------------------------------------------
# per-module symbol tables
# ---------------------------------------------------------------------------

class ModuleTable:
    """What one module's names mean: imports, functions, top-level state."""

    def __init__(self, sf, package: str):
        self.sf = sf
        self.import_modules: Dict[str, str] = {}          # alias -> module fq
        self.import_objects: Dict[str, Tuple[str, str]] = {}  # alias -> (mod, name)
        self.functions: Dict[str, ast.AST] = {}           # top-level units
        self.globals: Set[str] = set()                    # module-level state
        if sf.tree is None:
            return
        for node in sf.tree.body:
            self._top_level(node, package)
        for cls in [n for n in sf.tree.body if isinstance(n, ast.ClassDef)]:
            for node in cls.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self.functions[f"{cls.name}.{node.name}"] = node

    def _top_level(self, node: ast.AST, package: str) -> None:
        if isinstance(node, ast.Import):
            for a in node.names:
                self.import_modules[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            base = _resolve_from(package, node.level, node.module)
            for a in node.names:
                if a.name == "*":
                    continue
                self.import_objects[a.asname or a.name] = (base, a.name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.functions[node.name] = node
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        self.globals.add(n.id)
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            if isinstance(node.target, ast.Name):
                self.globals.add(node.target.id)
        elif isinstance(node, (ast.For, ast.With, ast.Try, ast.If)):
            for sub in ast.iter_child_nodes(node):
                self._top_level(sub, package)


def _resolve_from(package: str, level: int, module: Optional[str]) -> str:
    """Resolve a (possibly relative) ``from X import ...`` base module."""
    if level == 0:
        return module or ""
    parts = package.split(".")
    if level > 1:
        parts = parts[: len(parts) - (level - 1)]
    return ".".join(parts + ([module] if module else []))


class Graph:
    """Module tables + symbol resolution over one :class:`Project`."""

    def __init__(self, project: Project):
        self.project = project
        self.tables: Dict[str, ModuleTable] = {}
        for fq, sf in project.by_module.items():
            package = fq if sf.relpath.endswith("__init__.py") else \
                fq.rsplit(".", 1)[0] if "." in fq else ""
            self.tables[fq] = ModuleTable(sf, package)

    def resolve_symbol(self, module: str, name: str,
                       _depth: int = 0) -> Optional[Tuple[str, str]]:
        """Follow re-exports to the (module, function) that defines
        ``name`` — or None when it lives outside the project."""
        if _depth > 8:
            return None
        t = self.tables.get(module)
        if t is None:
            return None
        if name in t.functions:
            return (module, name)
        if name in t.import_objects:
            mod, orig = t.import_objects[name]
            if mod in self.tables and orig not in self.tables.get(mod).functions \
                    and f"{mod}.{orig}" in self.tables:
                return None  # `from . import submod` — a module, not a func
            return self.resolve_symbol(mod, orig, _depth + 1)
        return None

    def dotted_of(self, import_modules: Dict[str, str],
                  import_objects: Dict[str, Tuple[str, str]], node: ast.AST,
                  locals_: Set[str]) -> Optional[str]:
        """Best-effort fully-qualified dotted name of a Name/Attribute
        chain, resolving the base through the given import maps (module
        imports merged with any function-level imports). External bases
        resolve to their real module path (``np.random.default_rng``
        -> ``numpy.random.default_rng``); unresolvable (locals, call
        results) -> None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = node.id
        parts.reverse()
        if base in locals_:
            return None
        if base in import_modules:
            return ".".join([import_modules[base]] + parts)
        if base in import_objects:
            mod, orig = import_objects[base]
            # `from . import game` imports a submodule; `from .game import f`
            # imports an object — both land in import_objects
            sub = f"{mod}.{orig}" if mod else orig
            if sub in self.tables or self.project.module(sub):
                return ".".join([sub] + parts)
            return ".".join([mod, orig] + parts) if mod else \
                ".".join([orig] + parts)
        if base in {"print", "open", "input", "float", "int", "bool"}:
            return ".".join(["builtins", base] + parts)
        return None


# ---------------------------------------------------------------------------
# unit analysis
# ---------------------------------------------------------------------------

def _unit_locals(fn: ast.AST) -> Set[str]:
    """Every name bound inside the unit (params, assignments, loop targets,
    comprehension vars, nested defs) — these shadow module symbols."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
            a = node.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs
                        + ([a.vararg] if a.vararg else [])
                        + ([a.kwarg] if a.kwarg else [])):
                names.add(arg.arg)
        elif isinstance(node, ast.Lambda):
            a = node.args
            for arg in a.posonlyargs + a.args + a.kwonlyargs:
                names.add(arg.arg)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.comprehension,)):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    names.add(n.id)
    return names


def _cast_exempt(arg: ast.AST, shape_locals: frozenset = frozenset()) -> bool:
    """float()/int()/bool() args that are trace-time legitimate: literals,
    shape/axis arithmetic, len() of static structures, config fields."""
    if isinstance(arg, ast.Constant):
        return True
    for node in ast.walk(arg):
        # .shape/.ndim/... and shape-derived accessors (joint_shape(),
        # state_shape()): static under jit by construction
        if isinstance(node, ast.Attribute) and (
                node.attr in {"ndim", "size", "dtype"}
                or "shape" in node.attr):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "len":
            return True
        if isinstance(node, ast.Name) and node.id in shape_locals:
            return True
    return False


def _shape_locals(fn: ast.AST) -> frozenset:
    """Names assigned from shape-derived expressions within the unit
    (``joint = ctx.joint_shape()``), one propagation level — enough for
    the repo's ``int(np.prod(joint))`` idiom."""
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _cast_exempt(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return frozenset(out)


class UnitScan:
    """One unit's outgoing edges + impurity findings."""

    def __init__(self, graph: Graph, module: str, qualname: str,
                 fn: ast.AST):
        self.graph = graph
        self.table = graph.tables[module]
        self.module = module
        self.qualname = qualname
        self.fn = fn
        self.locals = _unit_locals(fn)
        self.shape_locals = _shape_locals(fn)
        # module imports merged with function-level ones (the obs tap
        # machinery does `import jax` inside the function body)
        self.import_modules = dict(self.table.import_modules)
        self.import_objects = dict(self.table.import_objects)
        package = self.table.sf.module or ""
        for node in ast.walk(fn):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_modules[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                base = _resolve_from(package, node.level, node.module)
                for a in node.names:
                    if a.name != "*":
                        self.import_objects[a.asname or a.name] = (base, a.name)
        self.edges: Set[Tuple[str, str]] = set()
        self.findings: List[Tuple[int, str]] = []   # (line, message)
        self._scan()

    def _dotted(self, node: ast.AST) -> Optional[str]:
        return self.graph.dotted_of(self.import_modules, self.import_objects,
                                    node, self.locals)

    def _edge_for(self, node: ast.AST) -> None:
        if isinstance(node, ast.Name):
            if node.id in self.locals:
                return
            if node.id in self.table.functions:
                self.edges.add((self.module, node.id))
            elif node.id in self.import_objects:
                tgt = self.graph.resolve_symbol(self.module, node.id)
                if tgt:
                    self.edges.add(tgt)
        elif isinstance(node, ast.Attribute):
            dotted = self._dotted(node)
            if dotted and dotted.startswith(("repro.", "examples.",
                                             "benchmarks.")):
                mod, _, name = dotted.rpartition(".")
                tgt = self.graph.resolve_symbol(mod, name)
                if tgt:
                    self.edges.add(tgt)

    def _scan(self) -> None:
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Call):
                self._check_call(node)
            elif isinstance(node, (ast.Name, ast.Attribute)) and \
                    isinstance(getattr(node, "ctx", None), ast.Load):
                self._edge_for(node)   # bare references: callbacks, partial()
            elif isinstance(node, ast.Global):
                self.findings.append((node.lineno, (
                    "module-global mutation (`global "
                    + ", ".join(node.names) + "`) in traced code: the "
                    "artifact's behavior depends on when jit traced it")))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                self._check_store(node)

    def _check_call(self, node: ast.Call) -> None:
        func = node.func
        # .item() host-syncs no matter what the receiver resolves to
        if isinstance(func, ast.Attribute) and func.attr == "item" \
                and not node.args:
            self.findings.append((node.lineno, (
                "`.item()` in traced code: host sync on a traced value "
                "(TracerConversionError at best, silent constant at worst)")))
            return
        # mutation of module-level containers
        if isinstance(func, ast.Attribute) and func.attr in _MUTATORS \
                and isinstance(func.value, ast.Name) \
                and func.value.id not in self.locals \
                and func.value.id in self.table.globals:
            self.findings.append((node.lineno, (
                f"mutates module global `{func.value.id}.{func.attr}(...)` "
                "from traced code: retrace-dependent behavior")))
            return
        if isinstance(func, ast.Name) and func.id in {"float", "int", "bool"} \
                and func.id not in self.locals:
            if node.args and not _cast_exempt(node.args[0],
                                              self.shape_locals):
                self.findings.append((node.lineno, (
                    f"`{func.id}()` on a possibly-traced value: host "
                    "conversion — compute in jnp, or mark the line "
                    "# lint: host-ok(reason) if the value is static")))
            return
        dotted = self._dotted(func)
        if dotted:
            msg = _impure_call(dotted)
            if msg:
                self.findings.append((node.lineno, msg))

    def _check_store(self, node: ast.AST) -> None:
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for t in targets:
            base = t
            while isinstance(base, (ast.Attribute, ast.Subscript)):
                base = base.value
            if isinstance(base, ast.Name) and base.id not in self.locals \
                    and base.id in self.table.globals and base is not t:
                self.findings.append((node.lineno, (
                    f"writes module global `{base.id}` from traced code: "
                    "retrace-dependent behavior")))


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------

def _registered_step_roots(graph: Graph) -> Iterable[Tuple[str, str]]:
    """Extra roots: functions statically resolvable at
    ``register_technique(name, fn)`` / ``step=fn`` call sites, so external
    solver registrations inside the package are walked without editing
    ``TRACED_ROOTS``."""
    for fq, table in graph.tables.items():
        if table.sf.tree is None or not fq.startswith("repro."):
            continue
        for node in ast.walk(table.sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, (ast.Name, ast.Attribute))):
                continue
            name = (node.func.id if isinstance(node.func, ast.Name)
                    else node.func.attr)
            if name != "register_technique":
                continue
            cands = list(node.args[1:2]) + [
                kw.value for kw in node.keywords
                if kw.arg in ("step", "solve_epoch")]
            for cand in cands:
                if isinstance(cand, ast.Name):
                    tgt = graph.resolve_symbol(fq, cand.id)
                elif isinstance(cand, ast.Attribute):
                    dotted = graph.dotted_of(table.import_modules,
                                             table.import_objects, cand, set())
                    if not dotted:
                        continue
                    mod, _, nm = dotted.rpartition(".")
                    tgt = graph.resolve_symbol(mod, nm)
                else:
                    continue
                if tgt:
                    yield tgt


def check(project: Project) -> List[Violation]:
    graph = Graph(project)
    out: List[Violation] = []

    worklist: List[Tuple[str, str]] = []
    for mod, name in TRACED_ROOTS:
        table = graph.tables.get(mod)
        if table is None or name not in table.functions:
            out.append(Violation(
                "src/repro/lint/purity.py", 1, "purity",
                f"declared traced root `{mod}:{name}` not found — update "
                "TRACED_ROOTS or restore the function (an unresolved root "
                "silently un-checks everything reachable from it)"))
            continue
        worklist.append((mod, name))
    worklist.extend(_registered_step_roots(graph))

    seen: Set[Tuple[str, str]] = set()
    while worklist:
        mod, name = worklist.pop()
        if (mod, name) in seen:
            continue
        seen.add((mod, name))
        table = graph.tables.get(mod)
        fn = table.functions.get(name) if table else None
        if fn is None:
            continue
        scan = UnitScan(graph, mod, name, fn)
        rel = table.sf.relpath
        for line, msg in scan.findings:
            pragma = project.pragma_at(rel, line, "host-ok")
            if pragma is not None:
                project.use_pragma(rel, line)
                continue
            out.append(Violation(rel, line, "purity",
                                 f"{msg} [reached from `{mod}:{name}`]"))
        worklist.extend(scan.edges)
    return out
