"""Structural invariants the solvers assume — checked, not hoped for.

The game-theoretic solvers and the fault executor lean on facts the type
system cannot see: routing tensors live on a probability simplex, demand
and prices are nonnegative, every division inside traced code is guarded
against zero denominators (an unguarded ``x / rho`` NaN-poisons a whole
scan, and ``jnp.where`` does not save you from the NaN *gradient*). This
checker pins those facts two ways:

- **statically** (:func:`check`): every division reachable from the traced
  roots (``repro.lint.purity.TRACED_ROOTS``) inside the core simulation
  modules must have a *provably positive* denominator — a positive
  literal/constant, ``jnp.maximum(x, eps)``, ``jnp.clip(x, lo, ...)`` with
  ``lo > 0``, ``1.0 - clip(x, 0, hi)`` with ``hi < 1``, or products/sums
  thereof. The declared simplex-normalization sites (``SIMPLEX_SITES``)
  must exist and normalize along the declared axis — a refactor that turns
  ``axis=-1`` into ``axis=0`` re-normalizes across the wrong dimension
  while keeping every shape legal, which is exactly the bug class this
  rules out. The nonnegativity tables below are cross-checked against
  ``repro.lint.pytrees.SCHEMAS`` so they cannot drift from the real field
  sets.
- **at runtime** (:func:`validate_bounds`, opt-in): an ``EnvParams`` /
  ``FaultTrace`` instance is checked leaf-by-leaf — nonnegative where
  declared, simplex fields summing to 1 along the declared axis.

Escapes use the reasoned ``# lint: unit-ok(reason)`` pragma on the
offending line, stale-checked like every pragma.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .project import Project, Violation
from .purity import Graph, TRACED_ROOTS, UnitScan, _registered_step_roots
from .pytrees import SCHEMAS
from .units import _const_fold

#: modules whose traced arithmetic gets the division-guard treatment —
#: host-side setup (build_env, rtt_matrix, capability derivation) divides
#: by python ints with explicit branches and is out of scope by reachability
BOUNDS_MODULES = (
    "repro.dcsim.env",
    "repro.dcsim.latency",
    "repro.faults.failover",
)

#: functions positive by construction (COP >= COP_MIN > 0, 1/(1-rho) >= 1)
POSITIVE_CALLS = {"power_cop", "cop", "queue_factor"}

#: (module, function, normalized name, required jnp.sum axis) — the simplex
#: projections every routing consumer assumes; the axis is load-bearing
SIMPLEX_SITES = (
    ("repro.dcsim.env", "project_feasible", "w", 1),
    ("repro.faults.failover", "_redistribute", "w", -1),
)

#: runtime nonnegativity: physical quantities that must never be negative
#: (demand, capacity, prices, intensities, fault multipliers). ``rp`` is
#: deliberately absent — renewable displacement enters ``grid_power`` as a
#: subtraction and the profile itself is clipped at source.
NONNEG_FIELDS: Dict[str, Tuple[str, ...]] = {
    "EnvParams": (
        "er", "it_idle", "it_dyn", "eff", "rp", "carbon", "eprice",
        "peak_price", "alpha", "nprice", "sizes", "nn_total", "car",
        "avail", "rtt", "sla_ms", "sla_price", "sla_weight",
    ),
    "FaultTrace": (
        "avail_mult", "rtt_extra_ms", "price_mult", "carbon_mult",
    ),
}

#: runtime simplex fields: class -> {field: axis the field sums to 1 along}
SIMPLEX_FIELDS: Dict[str, Dict[str, int]] = {
    "EnvParams": {"origin": 0},   # (S, I, 24): source mix per task-hour
}


# ---------------------------------------------------------------------------
# positivity recognizer
# ---------------------------------------------------------------------------

def _terminal(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _module_consts(graph: Graph, module: str) -> Dict[str, float]:
    """Positive top-level numeric constants visible in ``module`` — its own
    assignments plus ``from x import NAME`` re-exports, one hop."""
    out: Dict[str, float] = {}
    table = graph.tables.get(module)
    if table is None or table.sf.tree is None:
        return out

    def harvest(tree: ast.Module, into: Dict[str, float]) -> None:
        for node in tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                v = _const_fold(node.value)
                if v is not None:
                    into[node.targets[0].id] = v

    harvest(table.sf.tree, out)
    for alias, (mod, name) in table.import_objects.items():
        other = graph.tables.get(mod)
        if other is None or other.sf.tree is None:
            continue
        theirs: Dict[str, float] = {}
        harvest(other.sf.tree, theirs)
        if name in theirs:
            out[alias] = theirs[name]
    return {k: v for k, v in out.items() if v > 0}


def _positive(node: ast.AST, consts: Dict[str, float],
              pos_locals: Set[str]) -> bool:
    """Conservatively: is this expression provably > 0? (A ``False`` means
    "not provable", not "negative" — this is a lint, not a proof.)"""
    v = _const_fold(node)
    if v is not None:
        return v > 0
    if isinstance(node, ast.Name):
        return node.id in pos_locals or node.id in consts
    if isinstance(node, ast.Subscript):
        return _positive(node.value, consts, pos_locals)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.UAdd):
        return _positive(node.operand, consts, pos_locals)
    if isinstance(node, ast.Call):
        name = _terminal(node.func)
        if name in ("maximum", "fmax", "max"):
            return any(_positive(a, consts, pos_locals) for a in node.args)
        if name == "clip":
            lo = node.args[1] if len(node.args) > 1 else next(
                (kw.value for kw in node.keywords
                 if kw.arg in ("a_min", "min")), None)
            return lo is not None and _positive(lo, consts, pos_locals)
        if name in POSITIVE_CALLS:
            return True
        return False
    if isinstance(node, ast.BinOp):
        left = _positive(node.left, consts, pos_locals)
        right = _positive(node.right, consts, pos_locals)
        if isinstance(node.op, ast.Add):
            # positive + (physically nonnegative) — the repo's 1 + rtt/scale
            return left or right
        if isinstance(node.op, (ast.Mult, ast.Div)):
            return left and right
        if isinstance(node.op, ast.Pow):
            return left
        if isinstance(node.op, ast.Sub):
            # c - clip(x, lo, hi) is positive when the constant c > hi
            c = _const_fold(node.left)
            if c is not None and isinstance(node.right, ast.Call) \
                    and _terminal(node.right.func) == "clip" \
                    and len(node.right.args) > 2:
                hi = node.right.args[2]
                hv = _const_fold(hi)
                if hv is None and isinstance(hi, ast.Name):
                    hv = consts.get(hi.id)
                return hv is not None and c > hv
    return False


def _positive_locals(fn: ast.AST, consts: Dict[str, float]) -> Set[str]:
    """Names assigned from provably-positive expressions, two propagation
    rounds — enough for ``width = SLA_SOFTNESS * jnp.maximum(sla_ms, eps)``."""
    out: Set[str] = set()
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and _positive(node.value, consts, out):
                out.add(node.targets[0].id)
    return out


# ---------------------------------------------------------------------------
# static checks
# ---------------------------------------------------------------------------

def _reachable(graph: Graph) -> Set[Tuple[str, str]]:
    """The traced call-graph closure, same walk as the purity checker."""
    worklist: List[Tuple[str, str]] = []
    for mod, name in TRACED_ROOTS:
        table = graph.tables.get(mod)
        if table is not None and name in table.functions:
            worklist.append((mod, name))
    worklist.extend(_registered_step_roots(graph))
    seen: Set[Tuple[str, str]] = set()
    while worklist:
        mod, name = worklist.pop()
        if (mod, name) in seen:
            continue
        seen.add((mod, name))
        table = graph.tables.get(mod)
        fn = table.functions.get(name) if table else None
        if fn is None:
            continue
        worklist.extend(UnitScan(graph, mod, name, fn).edges)
    return seen


def _check_divisions(project: Project, graph: Graph,
                     out: List[Violation]) -> None:
    reachable = _reachable(graph)
    consts_cache: Dict[str, Dict[str, float]] = {}
    for mod, name in sorted(reachable):
        if mod not in BOUNDS_MODULES:
            continue
        table = graph.tables[mod]
        fn = table.functions.get(name)
        if fn is None:
            continue
        if mod not in consts_cache:
            consts_cache[mod] = _module_consts(graph, mod)
        consts = consts_cache[mod]
        pos = _positive_locals(fn, consts)
        rel = table.sf.relpath
        for node in ast.walk(fn):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.Div)):
                continue
            if _positive(node.right, consts, pos):
                continue
            line = node.lineno
            if project.pragma_at(rel, line, "unit-ok") is not None:
                project.use_pragma(rel, line)
                continue
            out.append(Violation(
                rel, line, "bounds",
                f"division in traced code (`{mod}:{name}`) whose "
                "denominator is not provably positive — guard with "
                "jnp.maximum(x, eps) (an unguarded zero NaN-poisons the "
                "scan and its gradients), or mark the line "
                "# lint: unit-ok(reason)"))


def _sum_axis(call: ast.Call) -> Optional[float]:
    for kw in call.keywords:
        if kw.arg == "axis":
            return _const_fold(kw.value)
    return None


def _check_simplex_sites(project: Project, graph: Graph,
                         out: List[Violation]) -> None:
    for mod, func, var, axis in SIMPLEX_SITES:
        table = graph.tables.get(mod)
        fn = table.functions.get(func) if table else None
        if fn is None:
            out.append(Violation(
                "src/repro/lint/bounds.py", 1, "bounds",
                f"declared simplex site `{mod}:{func}` not found — update "
                "SIMPLEX_SITES or restore the function"))
            continue
        rel = table.sf.relpath
        found = False
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == var
                    and isinstance(node.value, ast.BinOp)
                    and isinstance(node.value.op, ast.Div)):
                continue
            found = True
            # denominator must be maximum(sum(..., axis=AXIS, keepdims), eps)
            den = node.value.right
            sum_call = None
            if isinstance(den, ast.Call) \
                    and _terminal(den.func) in ("maximum", "fmax") \
                    and den.args and isinstance(den.args[0], ast.Call) \
                    and _terminal(den.args[0].func) == "sum":
                sum_call = den.args[0]
            elif isinstance(den, ast.Call) \
                    and _terminal(den.func) == "sum":
                sum_call = den
            if sum_call is None:
                out.append(Violation(
                    rel, node.lineno, "bounds",
                    f"`{func}` normalizes `{var}` without a "
                    "jnp.maximum(jnp.sum(...), eps)-guarded denominator"))
                continue
            got = _sum_axis(sum_call)
            if got is not None and float(got).is_integer():
                got = int(got)
            if got != axis:
                out.append(Violation(
                    rel, node.lineno, "bounds",
                    f"`{func}` normalizes `{var}` along axis {got!r} but "
                    f"the simplex contract requires axis {axis} — every "
                    "consumer assumes rows on that axis sum to 1"))
        if not found:
            out.append(Violation(
                rel, fn.lineno, "bounds",
                f"`{func}` no longer contains the `{var} = ... / ...` "
                "simplex normalization — update SIMPLEX_SITES if the "
                "projection moved"))


def _check_field_tables(out: List[Violation]) -> None:
    """NONNEG_FIELDS / SIMPLEX_FIELDS must name real schema fields."""
    for table, per_cls in (("NONNEG_FIELDS", NONNEG_FIELDS),
                           ("SIMPLEX_FIELDS", SIMPLEX_FIELDS)):
        for cls, fields in per_cls.items():
            if cls not in SCHEMAS:
                out.append(Violation(
                    "src/repro/lint/bounds.py", 1, "bounds",
                    f"{table} names unknown class `{cls}` — keep it in "
                    "sync with repro.lint.pytrees.SCHEMAS"))
                continue
            known = SCHEMAS[cls][1]
            for f in fields:
                if f not in known:
                    out.append(Violation(
                        "src/repro/lint/bounds.py", 1, "bounds",
                        f"{table}[{cls!r}] names unknown field `{f}` — "
                        "keep it in sync with repro.lint.pytrees.SCHEMAS"))


def check(project: Project) -> List[Violation]:
    graph = Graph(project)
    out: List[Violation] = []
    _check_field_tables(out)
    _check_divisions(project, graph, out)
    _check_simplex_sites(project, graph, out)
    return out


# ---------------------------------------------------------------------------
# runtime side (opt-in, mirrors repro.lint.pytrees.validate)
# ---------------------------------------------------------------------------

def validate_bounds(tree, atol: float = 1e-5) -> None:
    """Check a live ``EnvParams``/``FaultTrace`` against the declared
    bounds: nonnegative where NONNEG_FIELDS says so, summing to 1 along
    the declared axis where SIMPLEX_FIELDS says so. Raises ``ValueError``
    listing every violated field. Host-side (numpy) — safe outside jit."""
    import numpy as np

    cls = type(tree).__name__
    problems: List[str] = []
    for field in NONNEG_FIELDS.get(cls, ()):
        leaf = np.asarray(getattr(tree, field))
        if leaf.size and float(leaf.min()) < -atol:
            problems.append(
                f"{cls}.{field}: min {float(leaf.min()):g} < 0 "
                "(declared nonnegative)")
    for field, axis in SIMPLEX_FIELDS.get(cls, {}).items():
        leaf = np.asarray(getattr(tree, field))
        if leaf.size == 0:
            continue
        if float(leaf.min()) < -atol:
            problems.append(f"{cls}.{field}: negative mass "
                            f"({float(leaf.min()):g})")
        sums = leaf.sum(axis=axis)
        err = float(np.abs(sums - 1.0).max())
        if err > atol:
            problems.append(
                f"{cls}.{field}: sums along axis {axis} deviate from 1 "
                f"by up to {err:g} (declared simplex)")
    if problems:
        raise ValueError("bounds violations:\n  " + "\n  ".join(problems))
