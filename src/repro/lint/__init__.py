"""``repro.lint`` — the repo's own static-analysis pass.

Six static checkers over the codebase's load-bearing invariants, plus a
runtime sanitizer:

==============  ============================================================
checker         invariant
==============  ============================================================
``purity``      nothing host-side (clocks, ``np.random``, ``.item()``,
                global mutation, un-pragma'd callbacks) is reachable from
                jitted roots
``compile-key`` every trace-influencing ``ExperimentSpec`` field joins the
                engine compile key (the PR 6/7/8 stale-artifact bug class)
``pytree``      ``EnvParams`` / ``FaultTrace`` / ``CapabilityBundle`` match
                their declared shape schemas; construction is total
``taps``        every ``obs.tap("...")`` literal is a declared tap name
``units``       units of measure propagate consistently through the
                simulator core: no ``$/kWh + kg/kWh``, no bare magic scale
                factors, ``_usd``/``_kg``/``_ms`` metric keys carry their
                suffix unit, declared signatures/field tables hold
``bounds``      traced divisions are guarded positive; routing tensors are
                normalized along the declared simplex axis; nonnegativity
                tables match the pytree schemas
``pragma``      suppressions are justified and still suppress something
==============  ============================================================

Run it: ``python -m repro.lint`` (or ``make lint``). The static side never
imports the modules it checks — no jax required. Suppressions:
``# lint: host-ok(reason)`` on a deliberate host call in traced code,
``# lint: runtime-only(reason)`` on a spec field that only selects runtime
inputs, ``# lint: unit(U)`` declaring a conversion constant's unit,
``# lint: unit-ok(reason)`` on a deliberate unit/bounds escape.

Runtime helpers (these do touch jax, lazily): :func:`validate` checks a
live pytree against its schema (shape unification, float64/weak-type
leaves); :func:`validate_bounds` checks nonnegativity/simplex bounds;
:func:`expect_compiles` / :func:`trace_count` pin compile counts in tests.
"""
from __future__ import annotations

from typing import List, Optional

from . import bounds, compile_key, purity, pytrees, taps, units
from .bounds import validate_bounds
from .project import Pragma, Project, Violation
from .pytrees import SCHEMAS, validate
from .runtime import expect_compiles, trace_count

__all__ = [
    "CHECKERS", "Pragma", "Project", "SCHEMAS", "Violation",
    "expect_compiles", "lint_project", "lint_repo", "trace_count",
    "validate", "validate_bounds",
]

#: slug -> checker, in report order
CHECKERS = {
    "purity": purity.check,
    "compile-key": compile_key.check,
    "pytree": pytrees.check,
    "taps": taps.check,
    "units": units.check,
    "bounds": bounds.check,
}


def lint_project(project: Project) -> List[Violation]:
    """Run every checker over an already-loaded project. Pragma accounting
    (stale/malformed suppressions) runs last, once all checkers have had
    the chance to consume their pragmas."""
    out: List[Violation] = list(project.parse_violations())
    for check in CHECKERS.values():
        out.extend(check(project))
    out.extend(project.pragma_violations())
    return sorted(out, key=lambda v: (v.path, v.line, v.check, v.message))


def lint_repo(root: Optional[str] = None) -> List[Violation]:
    """Load the repo at ``root`` (default: this checkout) and lint it."""
    project = Project.load(root or Project.default_root())
    return lint_project(project)
