"""CLI driver: ``python -m repro.lint [--root DIR] [--check SLUG ...]``.

Exit status 0 when clean, 1 when any violation is found (2 on usage
errors, via argparse). Purely static — runs without jax installed.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from . import CHECKERS, lint_project
from .project import Project, Violation


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-native static analysis: trace purity, "
                    "compile-key completeness, pytree contracts, tap "
                    "registry")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: this checkout)")
    ap.add_argument("--check", action="append", choices=sorted(CHECKERS),
                    metavar="SLUG", dest="checks",
                    help="run only this checker (repeatable); default: all")
    args = ap.parse_args(argv)

    root = args.root or Project.default_root()
    project = Project.load(root)
    if not project.sources:
        print(f"repro.lint: no sources found under {root}", file=sys.stderr)
        return 2

    violations: List[Violation]
    if args.checks:
        violations = list(project.parse_violations())
        for slug in dict.fromkeys(args.checks):
            violations.extend(CHECKERS[slug](project))
        violations.extend(project.pragma_violations(include_stale=False))
        violations.sort(key=lambda v: (v.path, v.line, v.check, v.message))
    else:
        violations = lint_project(project)

    for v in violations:
        print(v.render())
    n_files = len(project.sources)
    if violations:
        print(f"repro.lint: {len(violations)} violation(s) in {n_files} "
              "file(s) scanned", file=sys.stderr)
        return 1
    print(f"repro.lint: clean ({n_files} files, "
          f"{len(args.checks or CHECKERS)} checkers)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
