"""CLI driver: ``python -m repro.lint [--root DIR] [--check SLUG ...]
[--format text|github|json]``.

Exit status 0 when clean, 1 when any violation is found (2 on usage
errors, via argparse). Purely static — runs without jax installed.

Formats: ``text`` (the default ``path:line: [check] message``), ``github``
(workflow commands — ``::error file=...,line=...::...`` — so CI violations
annotate the offending PR lines), ``json`` (one object per violation, for
tooling).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from . import CHECKERS, lint_project
from .project import Project, Violation


def _render_github(v: Violation) -> str:
    # workflow commands eat raw newlines/%%; escape per the Actions spec
    msg = (v.message.replace("%", "%25").replace("\r", "%0D")
           .replace("\n", "%0A"))
    return (f"::error file={v.path},line={v.line},"
            f"title=repro.lint [{v.check}]::{msg}")


def _render_json(violations: List[Violation]) -> str:
    return json.dumps(
        [{"path": v.path, "line": v.line, "check": v.check,
          "message": v.message} for v in violations],
        indent=2)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-native static analysis: trace purity, "
                    "compile-key completeness, pytree contracts, tap "
                    "registry, units of measure, bounds invariants")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: this checkout)")
    ap.add_argument("--check", action="append", choices=sorted(CHECKERS),
                    metavar="SLUG", dest="checks",
                    help="run only this checker (repeatable); default: all")
    ap.add_argument("--format", choices=("text", "github", "json"),
                    default="text", dest="fmt",
                    help="violation output format (default: text)")
    args = ap.parse_args(argv)

    root = args.root or Project.default_root()
    project = Project.load(root)
    if not project.sources:
        print(f"repro.lint: no sources found under {root}", file=sys.stderr)
        return 2

    violations: List[Violation]
    if args.checks:
        violations = list(project.parse_violations())
        for slug in dict.fromkeys(args.checks):
            violations.extend(CHECKERS[slug](project))
        violations.extend(project.pragma_violations(include_stale=False))
        violations.sort(key=lambda v: (v.path, v.line, v.check, v.message))
    else:
        violations = lint_project(project)

    if args.fmt == "json":
        print(_render_json(violations))
    else:
        for v in violations:
            print(_render_github(v) if args.fmt == "github" else v.render())
    n_files = len(project.sources)
    if violations:
        print(f"repro.lint: {len(violations)} violation(s) in {n_files} "
              "file(s) scanned", file=sys.stderr)
        return 1
    if args.fmt != "json":
        print(f"repro.lint: clean ({n_files} files, "
              f"{len(args.checks or CHECKERS)} checkers)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
