"""Shared substrate for the ``repro.lint`` checkers: files, ASTs, pragmas.

The checkers are *static* — they parse source, never import the modules
they check (so ``python -m repro.lint`` runs without jax and cannot be
fooled by import-time state). Everything they share lives here:

- :class:`Project` — the file set under analysis. Loads ``src/repro`` (and
  ``examples``/``benchmarks`` for the call-site checkers), parses each file
  once, maps files to dotted module names so imports resolve across the
  package, and supports :meth:`Project.overlay` — swap one file's source
  for a modified string — which is how the tests seed regressions (delete a
  field from ``static_key``, typo a tap name) without touching the tree.
- :class:`Pragma` — the in-source suppression grammar
  ``# lint: <directive>(<reason>)``. Directives: ``host-ok`` (this line's
  host-side call from traced code is deliberate — the ``jax.debug.callback``
  escape hatch), ``runtime-only`` (this ``ExperimentSpec`` field selects
  runtime inputs, not the traced program), ``unit`` (declares the unit of
  the constant assigned on this line, e.g. ``# lint: unit(W/kW)`` — a
  *declaration*, consumed by ``repro.lint.units``), ``unit-ok`` (this
  line's unit finding is a deliberate escape). A pragma with an empty
  reason is itself a violation, and a pragma that suppresses nothing is
  reported as stale — suppressions cannot silently outlive their cause.
- :class:`Violation` — one finding: ``path:line: [checker] message``.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, List, NamedTuple, Optional

PRAGMA_RE = re.compile(r"#\s*lint:\s*([a-z-]+)\s*\(([^)]*)\)")

PRAGMA_DIRECTIVES = ("host-ok", "runtime-only", "unit", "unit-ok")


class Violation(NamedTuple):
    """One lint finding, sortable into file/line order."""
    path: str       # repo-relative
    line: int
    check: str      # checker slug: purity | compile-key | pytree | taps | pragma
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"


class Pragma(NamedTuple):
    directive: str
    reason: str
    line: int


class SourceFile:
    """One parsed source file: AST, dotted module name, pragma table."""

    def __init__(self, relpath: str, text: str, module: Optional[str]):
        self.relpath = relpath
        self.text = text
        self.module = module          # dotted name, None if unparseable
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(text, filename=relpath)
        except SyntaxError as e:  # surfaced as a violation by the driver
            self.parse_error = f"syntax error: {e.msg} (line {e.lineno})"
        # pragmas live in *comment tokens* only — the same text inside a
        # string literal (docs, the lint messages themselves) is not one
        self.pragmas: Dict[int, Pragma] = {}
        try:
            tokens = tokenize.generate_tokens(io.StringIO(text).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = PRAGMA_RE.search(tok.string)
                if m:
                    line = tok.start[0]
                    self.pragmas[line] = Pragma(m.group(1),
                                                m.group(2).strip(), line)
        except (tokenize.TokenError, IndentationError, SyntaxError):
            pass   # unparseable files already surface via parse_error


def _module_name(relpath: str) -> Optional[str]:
    """src/repro/core/game.py -> repro.core.game; examples/run_obs.py ->
    examples.run_obs (scripts get a synthetic name so alias resolution has
    something to hang onto)."""
    p = Path(relpath)
    parts = list(p.with_suffix("").parts)
    if parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


class Project:
    """The file set one lint run analyzes.

    ``sources`` maps repo-relative paths to :class:`SourceFile`;
    ``by_module`` indexes the importable ones by dotted name. ``overlay``
    returns a copy with one file's text replaced — the regression-seeding
    hook the tests use.
    """

    #: directories scanned relative to the repo root (missing ones skipped)
    SCAN_DIRS = ("src/repro", "examples", "benchmarks")

    def __init__(self, sources: Dict[str, SourceFile], root: Optional[Path]):
        self.sources = sources
        self.root = root
        self.by_module: Dict[str, SourceFile] = {
            sf.module: sf for sf in sources.values() if sf.module
        }
        self._used_pragmas: set = set()   # (relpath, line)

    @classmethod
    def load(cls, root) -> "Project":
        root = Path(root)
        sources: Dict[str, SourceFile] = {}
        for d in cls.SCAN_DIRS:
            base = root / d
            if not base.is_dir():
                continue
            for f in sorted(base.rglob("*.py")):
                rel = str(f.relative_to(root))
                sources[rel] = SourceFile(rel, f.read_text(),
                                          _module_name(rel))
        return cls(sources, root)

    @classmethod
    def default_root(cls) -> Path:
        """The repo root, located from this package's own position
        (``src/repro/lint/project.py`` -> three parents up)."""
        return Path(__file__).resolve().parents[3]

    def overlay(self, relpath: str, text: str) -> "Project":
        """A copy of the project with ``relpath``'s source replaced —
        regression seeding for the tests (the tree is untouched)."""
        sources = dict(self.sources)
        sources[relpath] = SourceFile(relpath, text,
                                      _module_name(relpath))
        return Project(sources, self.root)

    def module(self, dotted: str) -> Optional[SourceFile]:
        return self.by_module.get(dotted)

    def file(self, relpath: str) -> Optional[SourceFile]:
        return self.sources.get(relpath)

    # -- pragma bookkeeping --------------------------------------------------

    def pragma_at(self, relpath: str, line: int,
                  directive: str) -> Optional[Pragma]:
        sf = self.sources.get(relpath)
        if sf is None:
            return None
        p = sf.pragmas.get(line)
        return p if p is not None and p.directive == directive else None

    def use_pragma(self, relpath: str, line: int) -> None:
        self._used_pragmas.add((relpath, line))

    def pragma_violations(self, include_stale: bool = True) -> List[Violation]:
        """Malformed, unknown, and stale pragmas — suppressions are checked
        code too. Staleness is only meaningful after *every* checker has had
        the chance to consume its pragmas; partial runs (``--check``) pass
        ``include_stale=False``."""
        out: List[Violation] = []
        for rel, sf in self.sources.items():
            for line, p in sf.pragmas.items():
                if p.directive not in PRAGMA_DIRECTIVES:
                    out.append(Violation(
                        rel, line, "pragma",
                        f"unknown pragma directive {p.directive!r}; known: "
                        f"{PRAGMA_DIRECTIVES}"))
                elif not p.reason:
                    out.append(Violation(
                        rel, line, "pragma",
                        f"pragma {p.directive!r} needs a justification: "
                        "# lint: " + p.directive + "(why this is safe)"))
                elif include_stale and (rel, line) not in self._used_pragmas:
                    out.append(Violation(
                        rel, line, "pragma",
                        f"stale pragma {p.directive!r}: it no longer "
                        "suppresses any finding — delete it"))
        return out

    def parse_violations(self) -> List[Violation]:
        return [Violation(rel, 1, "parse", sf.parse_error)
                for rel, sf in self.sources.items() if sf.parse_error]
