"""Compile-key completeness: every trace-influencing ``ExperimentSpec``
field must join the engine compile key.

This is the PR 6/7/8 bug class, mechanized. Three consecutive PRs each
added a field that changes the traced program (``taps``, faultedness,
``workload``) and each initially forgot to join it to the key tuple in
``repro.core.experiment`` — so a stale jitted artifact kept dispatching for
specs that described a different program. The checker cross-references, in
source, the four places a field can appear:

1. the ``ExperimentSpec`` dataclass fields,
2. the ``self.<field>`` reads inside ``static_key``,
3. the parameters of ``_day_core`` (what actually shapes the traced
   program) and ``_compiled_raw`` (the cache key arity),
4. the tuple ``_engine_key`` builds (the key ``run`` dispatches under).

and fails when they drift:

- a ``_day_core`` parameter that is not read by ``static_key`` — the
  seeded regression "delete ``workload`` from ``static_key``" trips here;
- a spec field that is neither in ``static_key``, nor ``engine``/``taps``
  (keyed via ``kind``/``effective_taps``), nor explicitly annotated
  ``# lint: runtime-only(reason)`` on its declaration line — adding a new
  field forces a decision: join the key, or declare (with a reason) that
  it only selects runtime inputs;
- a ``runtime-only`` field that *is* in ``static_key`` (contradiction);
- ``_engine_key``'s unpack order or return tuple drifting out of
  positional agreement with ``static_key`` / ``_compiled_raw`` (the key is
  splatted positionally — ``_compiled_raw(*key)`` — so order IS meaning);
- ``spec.effective_taps()`` missing from the key tuple (taps are
  trace-time liveness: an artifact traced under the wrong tap set either
  streams to nobody or never streams).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence

from .project import Project, Violation

EXPERIMENT_PATH = "src/repro/core/experiment.py"

#: fields keyed through a named transformation rather than static_key:
#: ``engine`` becomes the key's leading ``kind``; ``taps`` rides
#: ``spec.effective_taps()`` (tap liveness is trace-time state).
INDIRECTLY_KEYED = {"engine", "taps"}


def _find(tree: ast.Module, name: str,
          cls: Optional[str] = None) -> Optional[ast.AST]:
    for node in tree.body:
        if cls is None and isinstance(node, (ast.FunctionDef, ast.ClassDef)) \
                and node.name == name:
            return node
        if cls is not None and isinstance(node, ast.ClassDef) \
                and node.name == cls:
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef) and sub.name == name:
                    return sub
    return None


def _self_reads(fn: ast.AST) -> List[str]:
    """``self.X`` attribute reads, in source order."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            out.append(node.attr)
    return out


def _params(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args]


def _spec_fields(cls: ast.ClassDef) -> Dict[str, int]:
    """Dataclass field name -> declaration line."""
    return {n.target.id: n.lineno for n in cls.body
            if isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name)}


def _return_tuple(fn: ast.FunctionDef) -> Optional[Sequence[ast.expr]]:
    """The elements of the function's (last) ``return (a, b, ...)``."""
    rets = [n for n in ast.walk(fn) if isinstance(n, ast.Return)]
    for r in reversed(rets):
        if isinstance(r.value, ast.Tuple):
            return r.value.elts
    return None


def _key_element_name(e: ast.expr) -> Optional[str]:
    """Map one ``_engine_key`` return element to the spec concept it keys:
    plain names pass through; ``spec.effective_taps()`` counts as ``taps``."""
    if isinstance(e, ast.Name):
        return e.id
    if isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute) \
            and e.func.attr == "effective_taps":
        return "taps"
    return None


def check(project: Project) -> List[Violation]:
    sf = project.file(EXPERIMENT_PATH)
    if sf is None or sf.tree is None:
        return [Violation(EXPERIMENT_PATH, 1, "compile-key",
                          "cannot parse repro/core/experiment.py — the "
                          "compile-key contract is unverifiable")]
    out: List[Violation] = []
    rel = sf.relpath

    spec_cls = _find(sf.tree, "ExperimentSpec")
    static_key = _find(sf.tree, "static_key", cls="ExperimentSpec")
    effective_taps = _find(sf.tree, "effective_taps", cls="ExperimentSpec")
    day_core = _find(sf.tree, "_day_core")
    compiled_raw = _find(sf.tree, "_compiled_raw")
    engine_key = _find(sf.tree, "_engine_key")
    for name, node in (("ExperimentSpec", spec_cls),
                       ("ExperimentSpec.static_key", static_key),
                       ("_day_core", day_core),
                       ("_compiled_raw", compiled_raw),
                       ("_engine_key", engine_key)):
        if node is None:
            out.append(Violation(
                rel, 1, "compile-key",
                f"`{name}` not found — the compile-key contract this "
                "checker enforces has moved; update repro.lint.compile_key"))
    if any(n is None for n in (spec_cls, static_key, day_core,
                               compiled_raw, engine_key)):
        return out

    fields = _spec_fields(spec_cls)
    key_fields = [f for f in _self_reads(static_key)]

    # 1. every spec field is keyed, indirectly keyed, or declared runtime-only
    for field, line in fields.items():
        if field in key_fields:
            if project.pragma_at(rel, line, "runtime-only"):
                project.use_pragma(rel, line)
                out.append(Violation(
                    rel, line, "compile-key",
                    f"spec field `{field}` is declared runtime-only but IS "
                    "read by static_key — one of the two is wrong"))
            continue
        if field in INDIRECTLY_KEYED:
            continue
        pragma = project.pragma_at(rel, line, "runtime-only")
        if pragma is not None:
            project.use_pragma(rel, line)
            continue
        out.append(Violation(
            rel, line, "compile-key",
            f"ExperimentSpec field `{field}` is in no compile key: join it "
            "to static_key() if it can change the traced program, or "
            "annotate the field `# lint: runtime-only(reason)` if it only "
            "selects runtime inputs (the PR 6/7/8 stale-artifact bug class)"))

    # 2. `taps`/`engine` indirection actually holds
    if effective_taps is None or "taps" not in _self_reads(effective_taps):
        out.append(Violation(
            rel, spec_cls.lineno, "compile-key",
            "ExperimentSpec.effective_taps() no longer reads self.taps — "
            "the taps field would fall out of the compile key"))
    eng_reads = [n.attr for n in ast.walk(engine_key)
                 if isinstance(n, ast.Attribute)
                 and isinstance(n.value, ast.Name) and n.value.id == "spec"]
    if "engine" not in eng_reads:
        out.append(Violation(
            rel, engine_key.lineno, "compile-key",
            "_engine_key no longer reads spec.engine — the engine kind "
            "would fall out of the compile key"))

    # 3. every _day_core parameter that shapes the traced program is keyed
    for p in _params(day_core):
        if p in ("faulted", "taps"):   # joined downstream of static_key
            continue
        if p not in key_fields:
            out.append(Violation(
                rel, day_core.lineno, "compile-key",
                f"_day_core parameter `{p}` changes the traced program but "
                "is not read by ExperimentSpec.static_key() — engines would "
                "reuse a stale compiled artifact across different "
                f"`{p}` values"))

    # 4. _engine_key's static_key unpack preserves static_key's field order
    unpack: Optional[List[str]] = None
    for node in ast.walk(engine_key):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Tuple) \
                and isinstance(node.value, ast.Call) \
                and isinstance(node.value.func, ast.Attribute) \
                and node.value.func.attr == "static_key":
            unpack = [e.id for e in node.targets[0].elts
                      if isinstance(e, ast.Name)]
    if unpack is None:
        out.append(Violation(
            rel, engine_key.lineno, "compile-key",
            "_engine_key no longer unpacks spec.static_key() — key "
            "construction has drifted from the declared static fields"))
    elif unpack != key_fields:
        out.append(Violation(
            rel, engine_key.lineno, "compile-key",
            f"_engine_key unpacks static_key() as {unpack} but static_key "
            f"returns {key_fields} — the key tuple is splatted positionally "
            "(_compiled_raw(*key)), so order drift silently rebinds fields"))

    # 5. the key tuple lines up 1:1 with _compiled_raw's parameters
    raw_params = _params(compiled_raw)
    ret = _return_tuple(engine_key)
    if ret is None:
        out.append(Violation(
            rel, engine_key.lineno, "compile-key",
            "_engine_key does not return a tuple literal — the key's "
            "positional contract with _compiled_raw is unverifiable"))
    else:
        key_names = [_key_element_name(e) for e in ret]
        if "taps" not in key_names:
            out.append(Violation(
                rel, engine_key.lineno, "compile-key",
                "spec.effective_taps() is missing from _engine_key's tuple "
                "— tapped and untapped programs would share one artifact"))
        if len(key_names) != len(raw_params) or any(
                k is not None and k != p
                for k, p in zip(key_names, raw_params)):
            out.append(Violation(
                rel, engine_key.lineno, "compile-key",
                f"_engine_key tuple {key_names} does not line up with "
                f"_compiled_raw{tuple(raw_params)} — the key is applied "
                "positionally, so a mismatch rebinds every later field"))
    return out
