"""Proximal Policy Optimization in pure JAX (paper §5.3 and baseline [33]).

The MDP (paper §5.2): state = the current strategy (simplex fractions,
flattened); action = new desired-fraction logits; next state = the action's
fractions; reward = −objective (the paper minimizes, the agent maximizes).
The same machinery drives both the per-player GT-DRL agents (|D| actions)
and the joint-PPO baseline (|I|·|D| actions) — only the callbacks differ.

Fully jitted: rollouts are lax.scan over time, episodes are vmapped, and
update epochs are a scan over minibatch gradient steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from . import networks as nets


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    horizon: int = 8          # steps per episode
    episodes: int = 64        # parallel episodes per iteration
    iters: int = 12           # rollout+update cycles
    update_epochs: int = 4
    clip: float = 0.2
    gamma: float = 0.9
    lam: float = 0.95
    lr: float = 3e-3
    vf_coef: float = 0.5
    ent_coef: float = 1e-3
    hidden: Tuple[int, ...] = (64, 64)


class AgentState(NamedTuple):
    actor: Any
    critic: Any
    actor_opt: Any
    critic_opt: Any


def agent_init(key, state_dim: int, action_dim: int, cfg: PPOConfig) -> AgentState:
    k1, k2 = jax.random.split(key)
    actor = nets.actor_init(k1, state_dim, action_dim, cfg.hidden)
    critic = nets.critic_init(k2, state_dim, cfg.hidden)
    oc = AdamWConfig(lr=cfg.lr, weight_decay=0.0, grad_clip=1.0)
    return AgentState(actor, critic, adamw_init(actor, oc), adamw_init(critic, oc))


class Rollout(NamedTuple):
    states: jnp.ndarray    # (B, T, S)
    actions: jnp.ndarray   # (B, T, A) logits
    logps: jnp.ndarray     # (B, T)
    rewards: jnp.ndarray   # (B, T)
    values: jnp.ndarray    # (B, T+1)


def _rollout(
    key,
    agent: AgentState,
    state0: jnp.ndarray,                    # (B, S) initial states
    state_of: Callable[[jnp.ndarray], jnp.ndarray],   # logits -> next state
    reward_of: Callable[[jnp.ndarray], jnp.ndarray],  # logits -> scalar reward
    cfg: PPOConfig,
) -> Rollout:
    b = state0.shape[0]

    def step(carry, key_t):
        s = carry
        keys = jax.random.split(key_t, b)
        logits, logp = jax.vmap(lambda st, k: nets.actor_sample(agent.actor, st, k))(s, keys)
        r = jax.vmap(reward_of)(logits)
        v = jax.vmap(lambda st: nets.critic_value(agent.critic, st))(s)
        s_next = jax.vmap(state_of)(logits)
        return s_next, (s, logits, logp, r, v)

    keys = jax.random.split(key, cfg.horizon)
    s_last, (ss, aa, lp, rr, vv) = jax.lax.scan(step, state0, keys)
    v_last = jax.vmap(lambda st: nets.critic_value(agent.critic, st))(s_last)
    # scan stacks time first: (T, B, ...) -> (B, T, ...)
    tx = lambda x: jnp.swapaxes(x, 0, 1)
    values = jnp.concatenate([tx(vv), v_last[:, None]], axis=1)
    return Rollout(tx(ss), tx(aa), tx(lp), tx(rr), values)


def _gae(ro: Rollout, cfg: PPOConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    deltas = ro.rewards + cfg.gamma * ro.values[:, 1:] - ro.values[:, :-1]

    def back(carry, d):
        adv = d + cfg.gamma * cfg.lam * carry
        return adv, adv

    _, adv_rev = jax.lax.scan(back, jnp.zeros(deltas.shape[0]), deltas.T[::-1])
    adv = adv_rev[::-1].T
    returns = adv + ro.values[:, :-1]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    return adv, returns


def _update(agent: AgentState, ro: Rollout, adv, returns, cfg: PPOConfig) -> Tuple[AgentState, Dict]:
    s = ro.states.reshape(-1, ro.states.shape[-1])
    a = ro.actions.reshape(-1, ro.actions.shape[-1])
    lp_old = ro.logps.reshape(-1)
    adv_f = adv.reshape(-1)
    ret_f = returns.reshape(-1)
    oc = AdamWConfig(lr=cfg.lr, weight_decay=0.0, grad_clip=1.0)

    def actor_loss(actor):
        mu = jax.vmap(lambda st: nets.actor_mean(actor, st))(s)
        std = jnp.exp(jnp.clip(actor["log_std"], -4.0, 1.0))
        lp = nets.gaussian_logp(a, mu, std)
        ratio = jnp.exp(lp - lp_old)
        unclipped = ratio * adv_f
        clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv_f
        ent = jnp.sum(jnp.clip(actor["log_std"], -4.0, 1.0))
        return -jnp.mean(jnp.minimum(unclipped, clipped)) - cfg.ent_coef * ent

    def critic_loss(critic):
        v = jax.vmap(lambda st: nets.critic_value(critic, st))(s)
        return cfg.vf_coef * jnp.mean((v - ret_f) ** 2)

    def epoch(carry, _):
        ag = carry
        la, ga = jax.value_and_grad(actor_loss)(ag.actor)
        new_actor, aopt, _ = adamw_update(ga, ag.actor_opt, ag.actor, oc)
        lc, gc = jax.value_and_grad(critic_loss)(ag.critic)
        new_critic, copt, _ = adamw_update(gc, ag.critic_opt, ag.critic, oc)
        return AgentState(new_actor, new_critic, aopt, copt), (la, lc)

    agent, (la, lc) = jax.lax.scan(epoch, agent, None, length=cfg.update_epochs)
    return agent, {"actor_loss": la[-1], "critic_loss": lc[-1]}


def ppo_improve(
    key,
    agent: AgentState,
    state0_fn: Callable[[Any], jnp.ndarray],   # key -> (B, S) initial states
    state_of: Callable[[jnp.ndarray], jnp.ndarray],
    reward_of: Callable[[jnp.ndarray], jnp.ndarray],
    cfg: PPOConfig,
) -> Tuple[AgentState, Dict[str, jnp.ndarray]]:
    """Run ``iters`` × (rollout → GAE → clipped update)."""

    def it(carry, key_i):
        ag = carry
        k1, k2 = jax.random.split(key_i)
        ro = _rollout(k1, ag, state0_fn(k2), state_of, reward_of, cfg)
        adv, ret = _gae(ro, cfg)
        ag, losses = _update(ag, ro, adv, ret, cfg)
        return ag, (jnp.mean(ro.rewards), losses["actor_loss"])

    agent, (rew, al) = jax.lax.scan(it, agent, jax.random.split(key, cfg.iters))
    return agent, {"mean_reward": rew, "actor_loss": al}


def greedy_fractions(agent: AgentState, state: jnp.ndarray) -> jnp.ndarray:
    """Deterministic action: softmax of the policy mean."""
    return jax.nn.softmax(nets.actor_mean(agent.actor, state))


def average_agents(agents_b: AgentState) -> AgentState:
    """Collapse a leading batch axis by parameter averaging (parallel SGD).

    Float leaves (params, AdamW moments) are averaged; integer leaves (the
    optimizer step counters, identical across a batch of equal-length
    updates) take the first copy so their dtype survives.
    """
    def avg(x):
        if jnp.issubdtype(x.dtype, jnp.integer):
            return x[0]
        return jnp.mean(x, axis=0)

    return jax.tree_util.tree_map(avg, agents_b)
