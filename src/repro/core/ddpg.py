"""DDPG baseline (comparison technique (d), adapted from [32]).

Joint control: one actor maps the full flattened strategy (|I|·|D|) to new
logits for every player at once; the critic is Q(s, a). Off-policy with a
ring replay buffer, Gaussian exploration, soft target updates. The paper
finds DDPG's exploration ill-suited to this objective landscape — we keep
the implementation standard so that finding reproduces honestly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from . import networks as nets
from .game import GameContext, SolveResult, cloud_objective, uniform_fractions


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    steps: int = 200            # environment interactions per epoch solve
    batch: int = 64
    buffer: int = 512
    gamma: float = 0.9
    tau_soft: float = 0.02
    act_noise: float = 0.3
    lr: float = 1e-3
    hidden: Tuple[int, ...] = (64, 64)
    warmup: int = 32


class DDPGState(NamedTuple):
    actor: Any
    critic: Any
    target_actor: Any
    target_critic: Any
    actor_opt: Any
    critic_opt: Any
    buf_s: jnp.ndarray
    buf_a: jnp.ndarray
    buf_r: jnp.ndarray
    buf_s2: jnp.ndarray
    buf_n: jnp.ndarray  # filled count


def _q_init(key, sdim, adim, hidden):
    return nets.mlp_init(key, (sdim + adim, *hidden, 1), out_scale=1.0)


def _q(params, s, a):
    return nets.mlp_apply(params, jnp.concatenate([s, a], axis=-1))[..., 0]


def ddpg_init(key, ctx: GameContext, cfg: DDPGConfig) -> DDPGState:
    sdim = adim = int(np.prod(ctx.joint_shape()))
    k1, k2 = jax.random.split(key)
    actor = nets.mlp_init(k1, (sdim, *cfg.hidden, adim))
    critic = _q_init(k2, sdim, adim, cfg.hidden)
    oc = AdamWConfig(lr=cfg.lr, weight_decay=0.0)
    z = jnp.zeros
    return DDPGState(
        actor, critic, actor, critic,
        adamw_init(actor, oc), adamw_init(critic, oc),
        z((cfg.buffer, sdim)), z((cfg.buffer, adim)), z((cfg.buffer,)),
        z((cfg.buffer, sdim)), jnp.zeros((), jnp.int32),
    )


def _fractions(logits_flat: jnp.ndarray, joint_shape) -> jnp.ndarray:
    """Flat actor logits -> joint strategy ((I, D) or routed (S, I, D))."""
    return jax.nn.softmax(logits_flat.reshape(joint_shape), axis=-1)


def solve_epoch(key, ctx: GameContext, peak_state: jnp.ndarray,
                cfg: DDPGConfig = DDPGConfig()) -> SolveResult:
    joint = ctx.joint_shape()
    sdim = adim = int(np.prod(joint))
    state = ddpg_init(key, ctx, cfg)
    oc = AdamWConfig(lr=cfg.lr, weight_decay=0.0)

    f0 = uniform_fractions(ctx)
    scale = jnp.abs(cloud_objective(ctx, f0, peak_state)) + 1e-6

    def reward(logits_flat):
        return -cloud_objective(ctx, _fractions(logits_flat, joint), peak_state) / scale

    def env_step(s, a):
        r = reward(a)
        s2 = _fractions(a, joint).reshape(-1)
        return r, s2

    def td_update(st: DDPGState, batch_idx):
        s, a = st.buf_s[batch_idx], st.buf_a[batch_idx]
        r, s2 = st.buf_r[batch_idx], st.buf_s2[batch_idx]
        a2 = jax.vmap(lambda x: nets.mlp_apply(st.target_actor, x))(s2)
        q_tgt = r + cfg.gamma * jax.vmap(lambda x, y: _q(st.target_critic, x, y))(s2, a2)

        def c_loss(c):
            q = jax.vmap(lambda x, y: _q(c, x, y))(s, a)
            return jnp.mean((q - q_tgt) ** 2)

        _, gc = jax.value_and_grad(c_loss)(st.critic)
        critic, copt, _ = adamw_update(gc, st.critic_opt, st.critic, oc)

        def a_loss(actor):
            acts = jax.vmap(lambda x: nets.mlp_apply(actor, x))(s)
            return -jnp.mean(jax.vmap(lambda x, y: _q(critic, x, y))(s, acts))

        _, ga = jax.value_and_grad(a_loss)(st.actor)
        actor, aopt, _ = adamw_update(ga, st.actor_opt, st.actor, oc)
        soft = lambda t, o: jax.tree_util.tree_map(
            lambda a_, b_: (1 - cfg.tau_soft) * a_ + cfg.tau_soft * b_, t, o)
        return st._replace(
            actor=actor, critic=critic, actor_opt=aopt, critic_opt=copt,
            target_actor=soft(st.target_actor, actor),
            target_critic=soft(st.target_critic, critic),
        )

    def step(carry, key_t):
        st, s, best_f, best_v = carry
        k1, k2 = jax.random.split(key_t)
        a = nets.mlp_apply(st.actor, s) + cfg.act_noise * jax.random.normal(k1, (adim,))
        r, s2 = env_step(s, a)
        idx = jnp.mod(st.buf_n, cfg.buffer)
        st = st._replace(
            buf_s=st.buf_s.at[idx].set(s), buf_a=st.buf_a.at[idx].set(a),
            buf_r=st.buf_r.at[idx].set(r), buf_s2=st.buf_s2.at[idx].set(s2),
            buf_n=st.buf_n + 1,
        )
        hi = jnp.minimum(st.buf_n, cfg.buffer)
        batch_idx = jax.random.randint(k2, (cfg.batch,), 0, jnp.maximum(hi, 1))
        st = jax.lax.cond(st.buf_n >= cfg.warmup, lambda: td_update(st, batch_idx), lambda: st)
        f = _fractions(a, joint)
        v = cloud_objective(ctx, f, peak_state)
        better = v < best_v
        best_f = jnp.where(better, f, best_f)
        best_v = jnp.where(better, v, best_v)
        return (st, s2, best_f, best_v), r

    s0 = f0.reshape(-1)
    v0 = cloud_objective(ctx, f0, peak_state)
    (st, _, best_f, best_v), rs = jax.lax.scan(
        step, (state, s0, f0, v0), jax.random.split(key, cfg.steps))
    return SolveResult(best_f, {"best": best_v, "rewards": rs})
