"""The builtin technique registrations + the legacy evaluation entry points.

Every technique exposes ``step(key, state, ctx, peak_state, cfg) ->
(state, SolveResult)`` through the registry in ``repro.core.game``
(``register_technique`` plugs external solvers in without editing this
file); the engines drive any of them through the paper's experimental
protocol: one-hour epochs, monthly peak-demand state threaded through,
metrics from the *detailed* simulator (not the optimization estimate).

The engines themselves — and the single spec-keyed compile cache they all
share — live in ``repro.core.experiment``. The entry points below
(``run_day``, ``run_day_scan``, ``run_days_batched``, ``run_month``) are
kept as thin shims over ``ExperimentSpec`` for backward compatibility and
remain pinned bit-for-bit against their pre-spec outputs; new code should
use ``from repro.core import ExperimentSpec, run, sweep``.

Every engine takes ``routed=True`` to play the per-source routing game:
the action space grows to the (S, I, D) tensor, SLA misses are priced per
(source, task) path, and GT-DRL agents are sized for the (S, D) strategy.
With the degenerate S = 1 aggregate origin the routed engines run the
unrouted program and reproduce its numbers bit-for-bit.

Performance is tracked machine-readably: ``make bench-smoke`` runs
``benchmarks.run --only scenarios,engine --json BENCH_engine.json`` so every
perf PR appends loop-vs-scan-vs-batched day timings and GT-DRL round
timings to a committed JSON trajectory (see ``benchmarks/bench_engine.py``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..dcsim import env as E
from . import ddpg, force_directed, genetic, gt_drl, nash, ppo_joint
from . import game
from .game import GameContext, SolveResult

TECHNIQUES = ("fd", "ga", "nash", "ddpg", "ppo", "gt-drl")  # the paper's six

_MODS = {"fd": (force_directed, force_directed.FDConfig()),
         "ga": (genetic, genetic.GAConfig()),
         "nash": (nash, nash.NashConfig()),
         "ddpg": (ddpg, ddpg.DDPGConfig()),
         "ppo": (ppo_joint, ppo_joint.JointPPOConfig())}

stack_envs = E.stack_envs  # back-compat alias; the canonical home is dcsim.env

# builtin registrations: the five stateless baselines + stateful gt-drl.
# ``game.register_technique`` is the ONE lookup every engine now shares —
# the old get_scheduler/_solver_step KeyError duplication is gone.
for _name, (_mod, _cfg) in _MODS.items():
    game.register_technique(_name, _mod.solve_epoch, default_cfg=_cfg)
game.register_technique(
    "gt-drl", step=gt_drl.solve_epoch, default_cfg=gt_drl.GTDRLConfig(),
    init_state=lambda key, env, objective, cfg, routed, pretrain:
        gt_drl.deploy(key, env, objective, cfg, routed, pretrain),
    stateful=True)


@functools.lru_cache(maxsize=None)
def _stateful_solve(name: str, cfg, taps: frozenset = frozenset()) -> Callable:
    """One jitted epoch solver per (technique, config, obs tap set), shared
    across scheduler instances (gt-drl and any registered stateful
    technique). ``taps`` keys the cache so a tapped loop-engine solver is a
    separate artifact from the taps-off one (same rule as the compiled
    engines in ``experiment``)."""
    t = game.get_technique(name)
    cfg = t.resolve_cfg(cfg)
    step = t.step
    return jax.jit(lambda key, state, ctx, peak: step(key, state, ctx, peak, cfg))


# a re-registered name must not serve the stale jitted step
game.on_technique_change(_stateful_solve.cache_clear)


class StatefulScheduler:
    """Stateful wrapper for the loop engine: holds the solver carry (e.g.
    per-player agents) across epochs, advancing it each ``solve_epoch``.

    The ambient obs tap set at construction is pinned for the scheduler's
    lifetime: every dispatch traces under exactly that set, so the jitted
    artifact always matches its cache key."""

    def __init__(self, name: str, state0, cfg=None):
        self.state = state0
        self._taps = obs.active_taps()
        self._solve = _stateful_solve(name, cfg, self._taps)

    def solve_epoch(self, key, ctx: GameContext, peak_state) -> SolveResult:
        with obs.tracing(self._taps):
            self.state, res = self._solve(key, self.state, ctx, peak_state)
        return res


class GTDRLScheduler(StatefulScheduler):
    """Stateful wrapper: holds (pre)trained per-player agents across epochs.

    ``agents`` injects an existing deployed snapshot (deploy-once protocol);
    otherwise ``pretrain_key`` triggers offline pretraining, else fresh init.
    """

    def __init__(self, env: E.EnvParams, objective: str, cfg: Optional[gt_drl.GTDRLConfig] = None,
                 pretrain_key=None, agents=None, routed: bool = False):
        if agents is None:
            agents = gt_drl.deploy(pretrain_key, env, objective, cfg, routed,
                                   pretrain_agents=pretrain_key is not None)
        super().__init__("gt-drl", agents, cfg)

    @property
    def agents(self):
        return self.state

    @agents.setter
    def agents(self, value):
        self.state = value


def get_scheduler(name: str, env: E.EnvParams, objective: str,
                  pretrain_key=None, routed: bool = False, **overrides) -> Callable:
    """Returns solve_epoch(key, ctx, peak_state) -> SolveResult, jitted so a
    24-epoch day compiles once (GameContext is a pytree; tau is traced).
    ``routed`` sizes stateful solvers' carries for the (S, I, D) routing game
    (the stateless techniques read the joint-strategy shape off the ctx at
    solve time). Any technique registered via ``game.register_technique``
    resolves here — unknown names raise with the known list."""
    t = game.get_technique(name)
    # identity check, not the name: a re-registered "gt-drl" must take the
    # generic registry path below, with its own step/init_state
    if t.step is gt_drl.solve_epoch:
        return GTDRLScheduler(env, objective, overrides.get("cfg"), pretrain_key,
                              overrides.get("agents"), routed).solve_epoch
    cfg = t.resolve_cfg(overrides.get("cfg"))
    if t.stateful:
        state0 = overrides.get("state0")
        if state0 is None:
            state0 = t.init_state(
                pretrain_key if pretrain_key is not None else jax.random.PRNGKey(0),
                env, objective, cfg, routed, pretrain_key is not None)
        return StatefulScheduler(name, state0, cfg).solve_epoch
    step = t.step

    def solve(key, ctx, peak_state):
        return step(key, (), ctx, peak_state, cfg)[1]
    return jax.jit(solve)


# ---------------------------------------------------------------------------
# legacy entry points: thin shims over ExperimentSpec (kept, deprecated)
# ---------------------------------------------------------------------------

def _spec(technique, objective, engine, **kw):
    from . import experiment
    return experiment.ExperimentSpec(technique=technique, objective=objective,
                                     engine=engine, **kw)


def run_day_scan(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver_state0: Any = None,
    routed: bool = False,
) -> Dict[str, Any]:
    """One technique through a day as a single jitted lax.scan call.
    Deprecated shim over ``experiment.run(spec, env)`` with engine="scan"."""
    from . import experiment
    spec = _spec(technique, objective, "scan", seed=seed, hours=hours,
                 pretrain=pretrain, cfg=cfg_override, routed=routed)
    return experiment.run(spec, env, peak_state0=peak_state0,
                          solver_state0=solver_state0)


def run_days_batched(
    envs,
    technique: str,
    objective: str = "carbon",
    *,
    seeds: Optional[Sequence[int]] = None,
    hours: int = 24,
    pretrain: bool = True,
    cfg_override: Any = None,
    solver_state0: Any = None,
    routed: bool = False,
    shard: bool = False,
) -> Dict[str, Any]:
    """Evaluate a fleet of scenario-days in ONE compiled vmapped call.
    Deprecated shim over ``experiment.run(spec, envs)`` with
    engine="batched" (which also exposes ``shard=True`` device sharding)."""
    from . import experiment
    spec = _spec(technique, objective, "batched", hours=hours,
                 pretrain=pretrain, cfg=cfg_override, routed=routed,
                 seeds=None if seeds is None else tuple(seeds))
    return experiment.run(spec, envs, solver_state0=solver_state0, shard=shard)


def run_month(
    envs,
    technique: str,
    objective: str = "carbon",
    *,
    days: Optional[int] = None,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver_state0: Any = None,
    routed: bool = False,
) -> Dict[str, Any]:
    """Month-scale episode: a second-level lax.scan over days in ONE compile.
    Deprecated shim over ``experiment.run(spec, envs)`` with engine="month"."""
    from . import experiment
    spec = _spec(technique, objective, "month", days=days, seed=seed,
                 hours=hours, pretrain=pretrain, cfg=cfg_override,
                 routed=routed)
    return experiment.run(spec, envs, peak_state0=peak_state0,
                          solver_state0=solver_state0)


def run_day(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver: Optional[Callable] = None,
    solver_state0: Any = None,
    engine: str = "scan",
    routed: bool = False,
) -> Dict[str, Any]:
    """Run one technique through a day; returns per-epoch + total metrics.

    Deprecated shim over ``experiment.run``. ``engine="scan"`` compiles the
    whole day into one call; ``"loop"`` is the reference Python hour-loop. A
    prebuilt ``solver`` closure forces the loop engine (the closure may
    carry state across calls/runs); ``solver_state0`` injects initial solver
    state into the scan engine. ``routed`` plays the (S, I, D) routing game
    in either engine.
    """
    from . import experiment
    if engine not in ("scan", "loop"):
        raise ValueError(f"unknown engine {engine!r}; known: scan, loop")
    if solver is None and engine == "scan":
        return run_day_scan(env, technique, objective, seed=seed, hours=hours,
                            pretrain=pretrain, peak_state0=peak_state0,
                            cfg_override=cfg_override, solver_state0=solver_state0,
                            routed=routed)
    spec = _spec(technique, objective, "loop", seed=seed, hours=hours,
                 pretrain=pretrain, cfg=cfg_override, routed=routed)
    return experiment.run(spec, env, peak_state0=peak_state0, solver=solver)


def _stats(vals, curves) -> Dict[str, Any]:
    """mean ± stderr of daily totals + the mean per-epoch curve.

    The ``n > 1`` guard is load-bearing: a single daily total would put the
    ``ddof=1`` std (NaN at n=1) over ``sqrt(n)`` and poison every downstream
    mean±stderr table — single-run protocols report stderr 0.0 instead
    (regression-pinned in tests/test_obs.py)."""
    vals = np.asarray(vals, dtype=float)
    curves = np.asarray(curves, dtype=float)
    n = vals.shape[0]
    return {
        "mean": float(vals.mean()),
        "stderr": float(vals.std(ddof=1) / np.sqrt(n)) if n > 1 else 0.0,
        "curve_mean": curves.mean(axis=0).tolist(),
    }


def compare_techniques(
    envs,
    techniques=TECHNIQUES,
    objective: str = "carbon",
    *,
    hours: int = 24,
    seed0: int = 0,
    engine: str = "batched",
    cfg_overrides: Optional[Dict[str, Any]] = None,
    routed: bool = False,
    shard: bool = False,
    record: Any = None,
) -> Dict[str, Dict[str, Any]]:
    """The paper's protocol: several runs (one env per resampled arrival
    pattern), mean±stderr of daily totals. The ranked metric is daily carbon
    under ``objective="carbon"`` and daily total cost otherwise (``cost_usd``
    already includes the SLA-miss charge, so ``objective="cost_sla"`` ranks
    on the latency-priced bill).

    ``engine="batched"`` (default) drives ``run_days_batched`` once per
    technique — the whole env suite is one vmapped compile (sharded across
    devices with ``shard=True``), with stateful techniques deployed once
    (on ``PRNGKey(seed0 + 999)``) and broadcast through the scan carry.
    ``engine="loop"`` is the hour-loop parity reference with identical
    deploy-once semantics: each day starts from the same deployed snapshot,
    so both engines agree within float32 tolerance. ``cfg_overrides`` maps
    technique -> config. Any technique registered via
    ``game.register_technique`` can appear in ``techniques``.

    ``record`` (True, or a JSONL path) appends one spec-keyed RunRecord per
    technique — the ranked mean±stderr, its mean convergence curve, and the
    batched engine's compile/dispatch spans — so the comparison table is a
    regenerable artifact (``repro.obs.report`` renders the scoreboard).
    """
    if isinstance(envs, E.EnvParams):
        envs = [envs]
    envs = list(envs)
    if engine not in ("batched", "loop"):
        raise ValueError(f"unknown engine {engine!r}; known: batched, loop")
    overrides = dict(cfg_overrides or {})
    metric = "carbon_kg" if objective == "carbon" else "cost_usd"
    seeds = [seed0 + r for r in range(len(envs))]
    out: Dict[str, Dict[str, Any]] = {}

    def deployed_state(tdef, cfg):
        return tdef.init_state(jax.random.PRNGKey(seed0 + 999), envs[0],
                               objective, cfg, routed, True)

    def record_one(t, cfg):
        from . import experiment as X
        spec = X.ExperimentSpec(technique=t, objective=objective,
                                engine=engine if engine == "loop" else "batched",
                                routed=routed, hours=hours, cfg=cfg)
        spans = (None if engine == "loop"
                 else obs.engine_stat(X._engine_key(spec, shard=shard)))
        rec = obs.make_record(
            spec, kind="compare", curves={metric: out[t]["curve_mean"]},
            engine_spans=spans,
            extra={"metric": metric, "mean": out[t]["mean"],
                   "stderr": out[t]["stderr"], "runs": len(envs),
                   "totals": {metric: out[t]["mean"]}})
        obs.write_record(rec, record if isinstance(record, str) else None)

    if engine == "loop":
        for t in techniques:
            tdef = game.get_technique(t)
            cfg = overrides.get(t)
            state0 = deployed_state(tdef, cfg) if tdef.stateful else None
            solver = None if tdef.stateful else get_scheduler(
                t, envs[0], objective,
                **({"cfg": cfg} if cfg is not None else {}))
            vals, curves = [], []
            for r, env in enumerate(envs):
                s = (StatefulScheduler(t, state0, cfg).solve_epoch
                     if tdef.stateful else solver)
                res = run_day(env, t, objective, seed=seeds[r], hours=hours,
                              solver=s, engine="loop", routed=routed)
                vals.append(res["totals"][metric])
                curves.append([e[metric] for e in res["per_epoch"]])
            out[t] = _stats(vals, curves)
            if record:
                record_one(t, cfg)
        return out

    env_b = E.stack_envs(envs)
    for t in techniques:
        tdef = game.get_technique(t)
        cfg = overrides.get(t)
        state0 = deployed_state(tdef, cfg) if tdef.stateful else None
        res = run_days_batched(env_b, t, objective, seeds=seeds, hours=hours,
                               cfg_override=cfg, solver_state0=state0,
                               routed=routed, shard=shard)
        out[t] = _stats(res["totals"][metric], res["per_epoch"][metric])
        if record:
            record_one(t, cfg)
    return out
