"""Unified scheduler registry + the compiled simulation engines.

Every technique exposes ``solve_epoch(key, ctx, peak_state) -> SolveResult``;
the engines drive any of them through the paper's experimental protocol:
one-hour epochs, monthly peak-demand state threaded through, metrics from
the *detailed* simulator (not the optimization estimate).

Three engines share that protocol:

- ``engine="scan"`` (default): a day is ONE jitted call — a ``lax.scan``
  over epochs with (rng key, peak state, solver state) in the carry. Because
  the day is a single pure function of ``(env, key, peak0, state0)``, it
  vmaps across environments: ``run_days_batched`` evaluates a whole scenario
  suite × seeds fleet (``repro.scenarios``) in one compile, and
  ``compare_techniques`` (the paper's protocol, every table in §6) drives it
  once per technique. GT-DRL agents thread through the scan carry, so the
  deploy-once protocol needs no stateful Python closure.
- ``engine="month"`` (``run_month``): a second-level ``lax.scan`` over days
  threads the monthly peak state — and the GT-DRL agents — across a whole
  month of scanned days, making the peak-demand charge (eq. 6) a real
  planning signal instead of a per-day afterthought.
- ``engine="loop"``: the seed Python hour-loop, kept as the parity
  reference (used automatically when a prebuilt stateful ``solver`` closure
  is passed). Metrics accumulate on-device and transfer with a single
  ``jax.device_get`` at day end. All engines produce matching metrics for
  the same technique/seed.

Every engine takes ``routed=True`` to play the per-source routing game:
the action space grows to the (S, I, D) tensor, SLA misses are priced per
(source, task) path, and GT-DRL agents are sized for the (S, D) strategy.
With the degenerate S = 1 aggregate origin the routed engines run the
unrouted program and reproduce its numbers bit-for-bit.

Performance is tracked machine-readably: ``make bench-smoke`` runs
``benchmarks.run --only scenarios,engine --json BENCH_engine.json`` so every
perf PR appends loop-vs-scan-vs-batched day timings and GT-DRL round
timings to a committed JSON trajectory (see ``benchmarks/bench_engine.py``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dcsim import env as E
from . import ddpg, force_directed, genetic, gt_drl, nash, ppo_joint
from .game import GameContext, SolveResult, fractions_to_ar

TECHNIQUES = ("fd", "ga", "nash", "ddpg", "ppo", "gt-drl")

_MODS = {"fd": (force_directed, force_directed.FDConfig()),
         "ga": (genetic, genetic.GAConfig()),
         "nash": (nash, nash.NashConfig()),
         "ddpg": (ddpg, ddpg.DDPGConfig()),
         "ppo": (ppo_joint, ppo_joint.JointPPOConfig())}

_TOTAL_KEYS = ("carbon_kg", "cost_usd", "sla_miss_cost_usd", "violation")

stack_envs = E.stack_envs  # back-compat alias; the canonical home is dcsim.env


@functools.lru_cache(maxsize=None)
def _gtdrl_solve(cfg: gt_drl.GTDRLConfig) -> Callable:
    """One jitted gt-drl epoch solver per config (shared across instances)."""
    return jax.jit(
        lambda key, agents, ctx, peak: gt_drl.solve_epoch(key, agents, ctx, peak, cfg))


class GTDRLScheduler:
    """Stateful wrapper: holds (pre)trained per-player agents across epochs.

    ``agents`` injects an existing deployed snapshot (deploy-once protocol);
    otherwise ``pretrain_key`` triggers offline pretraining, else fresh init.
    """

    def __init__(self, env: E.EnvParams, objective: str, cfg: Optional[gt_drl.GTDRLConfig] = None,
                 pretrain_key=None, agents=None, routed: bool = False):
        self.cfg = cfg or gt_drl.GTDRLConfig()
        self.objective = objective
        if agents is not None:
            self.agents = agents
        elif pretrain_key is not None:
            self.agents = gt_drl.pretrain(pretrain_key, env, objective, self.cfg,
                                          routed)
        else:
            self.agents = gt_drl.init_agents(jax.random.PRNGKey(0), env, self.cfg,
                                             routed)
        self._solve = _gtdrl_solve(self.cfg)

    def solve_epoch(self, key, ctx: GameContext, peak_state) -> SolveResult:
        self.agents, res = self._solve(key, self.agents, ctx, peak_state)
        return res


def get_scheduler(name: str, env: E.EnvParams, objective: str,
                  pretrain_key=None, routed: bool = False, **overrides) -> Callable:
    """Returns solve_epoch(key, ctx, peak_state) -> SolveResult, jitted so a
    24-epoch day compiles once (GameContext is a pytree; tau is traced).
    ``routed`` sizes GT-DRL agents for the (S, I, D) routing game (the other
    techniques read the joint-strategy shape off the ctx at solve time)."""
    if name in _MODS:
        mod, default_cfg = _MODS[name]
        cfg = overrides.get("cfg", default_cfg)
        return jax.jit(functools.partial(mod.solve_epoch, cfg=cfg))
    if name == "gt-drl":
        sched = GTDRLScheduler(env, objective, overrides.get("cfg"), pretrain_key,
                               overrides.get("agents"), routed)
        return sched.solve_epoch
    raise KeyError(f"unknown technique {name!r}; known: {TECHNIQUES}")


# ---------------------------------------------------------------------------
# compiled day engine: one lax.scan over epochs == one jitted call per day
# ---------------------------------------------------------------------------

def _solver_step(technique: str, cfg) -> Callable:
    """step(key, state, ctx, peak) -> (state, SolveResult); state threads the
    scan carry (per-player agents for gt-drl, () for stateless solvers)."""
    if technique == "gt-drl":
        cfg = cfg or gt_drl.GTDRLConfig()

        def step(key, agents, ctx, peak):
            return gt_drl.solve_epoch(key, agents, ctx, peak, cfg)
        return step
    if technique not in _MODS:
        raise KeyError(f"unknown technique {technique!r}; known: {TECHNIQUES}")
    mod, default_cfg = _MODS[technique]
    cfg = cfg or default_cfg

    def step(key, state, ctx, peak):
        return state, mod.solve_epoch(key, ctx, peak, cfg=cfg)
    return step


@functools.lru_cache(maxsize=None)
def _day_core(technique: str, objective: str, hours: int, cfg,
              routed: bool = False) -> Callable:
    """day(env, key, peak0, state0) -> (peak, state, metrics (hours,)-dict).

    Pure and jit/vmap-friendly; the RNG key is split exactly as the
    reference loop does, so both engines see the same per-epoch keys.
    ``routed`` plays the (S, I, D) routing game instead of the (I, D) one.
    """
    step = _solver_step(technique, cfg)

    def day(env: E.EnvParams, key, peak0, state0):
        def body(carry, tau):
            key, peak, state = carry
            key, ks = jax.random.split(key)
            ctx = GameContext(env=env, tau=tau, objective=objective,
                              routed=routed)
            state, res = step(ks, state, ctx, peak)
            ar = fractions_to_ar(ctx, res.fractions)
            peak, m = E.step_epoch(env, peak, ar, tau)
            return (key, peak, state), m

        (_, peak, state), ms = jax.lax.scan(
            body, (key, peak0, state0), jnp.arange(hours, dtype=jnp.int32))
        return peak, state, ms

    return day


@functools.lru_cache(maxsize=None)
def _compiled_day(technique: str, objective: str, hours: int, cfg,
                  routed: bool = False) -> Callable:
    return jax.jit(_day_core(technique, objective, hours, cfg, routed))


@functools.lru_cache(maxsize=None)
def _compiled_batch(technique: str, objective: str, hours: int, cfg,
                    routed: bool = False) -> Callable:
    """One compile for a whole fleet: vmap the day core over (env, key)."""
    core = _day_core(technique, objective, hours, cfg, routed)
    return jax.jit(jax.vmap(core, in_axes=(0, 0, None, None)))


@functools.lru_cache(maxsize=None)
def _compiled_month(technique: str, objective: str, hours: int, cfg,
                    routed: bool = False) -> Callable:
    """month(env_days, keys, peak0, state0): scan the day core over days,
    threading (peak, solver state) — the monthly-peak charge accumulates."""
    day = _day_core(technique, objective, hours, cfg, routed)

    def month(env_days, keys, peak0, state0):
        def body(carry, x):
            peak, state = carry
            env, key = x
            peak, state, ms = day(env, key, peak, state)
            return (peak, state), (ms, peak)

        (peak, state), (ms, peaks) = jax.lax.scan(
            body, (peak0, state0), (env_days, keys))
        return peak, state, ms, peaks

    return jax.jit(month)


def _day_inputs(env, technique, objective, seed, pretrain, cfg,
                solver_state0=None, routed: bool = False):
    """Replicates the reference loop's key discipline + initial solver state.

    An injected ``solver_state0`` short-circuits state construction (no
    throwaway pretrain/init work) while keeping the key discipline intact.
    """
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    if solver_state0 is not None:
        return key, solver_state0
    if technique == "gt-drl":
        c = cfg or gt_drl.GTDRLConfig()
        state0 = (gt_drl.pretrain(kp, env, objective, c, routed) if pretrain
                  else gt_drl.init_agents(jax.random.PRNGKey(0), env, c, routed))
    else:
        state0 = ()
    return key, state0


def _format_day(ms, hours: int, technique: str, objective: str) -> Dict[str, Any]:
    """Stacked (hours,) metric arrays -> the run_day result dict."""
    host = {k: np.asarray(v).astype(float).tolist() for k, v in ms.items()}
    per_epoch = [{**{k: host[k][t] for k in host}, "tau": t} for t in range(hours)]
    totals = {k: 0.0 for k in _TOTAL_KEYS}
    for row in per_epoch:
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals, "technique": technique,
            "objective": objective}


def run_day_scan(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver_state0: Any = None,
    routed: bool = False,
) -> Dict[str, Any]:
    """One technique through a day as a single jitted lax.scan call.

    ``solver_state0`` injects an initial solver state (deployed GT-DRL
    agents), overriding the pretrain/init derived from ``seed``. ``routed``
    plays the per-source routing game over the (S, I, D) tensor.
    """
    key, state0 = _day_inputs(env, technique, objective, seed, pretrain,
                              cfg_override, solver_state0, routed)
    peak0 = peak_state0 if peak_state0 is not None else jnp.zeros((E.num_dcs(env),))
    day = _compiled_day(technique, objective, hours, cfg_override, routed)
    _, _, ms = day(env, key, peak0, state0)
    return _format_day(ms, hours, technique, objective)


def run_days_batched(
    envs,
    technique: str,
    objective: str = "carbon",
    *,
    seeds: Optional[Sequence[int]] = None,
    hours: int = 24,
    pretrain: bool = True,
    cfg_override: Any = None,
    solver_state0: Any = None,
    routed: bool = False,
) -> Dict[str, Any]:
    """Evaluate a fleet of scenario-days in ONE compiled vmapped call.

    ``envs``: a list of same-shape EnvParams (e.g. a materialized scenario
    suite) or an already-stacked batched EnvParams. ``seeds`` defaults to
    ``range(n)`` — one RNG stream per day, split exactly like ``run_day``.
    GT-DRL pretrains once (deploy-once) and the agents are broadcast;
    ``solver_state0`` injects an already-deployed snapshot instead.

    Returns ``{"totals": {k: (n,)}, "per_epoch": {k: (n, hours)}}`` numpy
    arrays plus bookkeeping fields.
    """
    if isinstance(envs, E.EnvParams) and envs.er.ndim == 2:
        envs = [envs]  # single env == batch of one (compare_techniques parity)
    if isinstance(envs, E.EnvParams):
        env_b, n = envs, int(envs.er.shape[0])
        env0 = jax.tree_util.tree_map(lambda x: x[0], envs)
    else:
        envs = list(envs)
        env_b, n = E.stack_envs(envs), len(envs)
        env0 = envs[0]
    seeds = list(range(n)) if seeds is None else list(seeds)
    if len(seeds) != n:
        raise ValueError(f"{len(seeds)} seeds for {n} scenario-days")

    # per-day keys split exactly as run_day splits them; gt-drl pretrains
    # ONCE on the first seed's pretrain key (deploy-once semantics)
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(s))[1] for s in seeds])
    _, state0 = _day_inputs(env0, technique, objective, seeds[0], pretrain,
                            cfg_override, solver_state0, routed)
    peak0 = jnp.zeros((E.num_dcs(env0),))

    batch = _compiled_batch(technique, objective, hours, cfg_override, routed)
    _, _, ms = batch(env_b, keys, peak0, state0)
    out = {k: np.asarray(v) for k, v in ms.items()}  # (n, hours) each
    totals = {k: out[k].sum(axis=1) for k in _TOTAL_KEYS}
    return {"totals": totals, "per_epoch": out, "technique": technique,
            "objective": objective, "seeds": seeds}


def run_month(
    envs,
    technique: str,
    objective: str = "carbon",
    *,
    days: Optional[int] = None,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver_state0: Any = None,
    routed: bool = False,
) -> Dict[str, Any]:
    """Month-scale episode: a second-level lax.scan over days in ONE compile.

    The monthly peak state (and, for gt-drl, the per-player agents) thread
    across days, so the peak-demand charge is a real planning signal: an
    assignment that sets a new monthly peak on day 3 pays for it all month.

    ``envs``: one EnvParams (repeated for ``days`` days, default 30), a list
    of per-day EnvParams or (name, EnvParams) rows (``scenarios.build_month``
    output works directly), or an already-stacked (days, ...) EnvParams. Day
    ``d`` uses the RNG stream of ``run_day(seed=seed + d)``, so day 0 with a
    zero peak matches ``run_day`` exactly.

    Returns per-day (days, hours) metric arrays, per-day totals, month
    totals, and the end-of-day monthly peak trajectory ``peak_w`` (days, D).
    """
    if isinstance(envs, E.EnvParams) and envs.er.ndim == 2:
        n = 30 if days is None else int(days)
        env0, env_days = envs, E.tile_env(envs, n)
    elif isinstance(envs, E.EnvParams):
        n = int(envs.er.shape[0])
        env0, env_days = jax.tree_util.tree_map(lambda x: x[0], envs), envs
    else:
        envs = [e if isinstance(e, E.EnvParams) else e[1] for e in envs]
        n, env0, env_days = len(envs), envs[0], E.stack_envs(envs)
    if days is not None and int(days) != n:
        raise ValueError(f"days={days} but {n} per-day envs were given")

    keys = jnp.stack(
        [jax.random.split(jax.random.PRNGKey(seed + d))[1] for d in range(n)])
    _, state0 = _day_inputs(env0, technique, objective, seed, pretrain,
                            cfg_override, solver_state0, routed)
    peak0 = peak_state0 if peak_state0 is not None else jnp.zeros((E.num_dcs(env0),))

    month = _compiled_month(technique, objective, hours, cfg_override, routed)
    final_peak, _, ms, peaks = month(env_days, keys, peak0, state0)
    per_day = {k: np.asarray(v) for k, v in ms.items()}  # (n, hours) each
    day_totals = {k: per_day[k].sum(axis=1) for k in _TOTAL_KEYS}
    return {"per_day": per_day, "day_totals": day_totals,
            "totals": {k: float(day_totals[k].sum()) for k in _TOTAL_KEYS},
            "peak_w": np.asarray(peaks), "final_peak_w": np.asarray(final_peak),
            "days": n, "technique": technique, "objective": objective}


# ---------------------------------------------------------------------------
# day protocol entry points
# ---------------------------------------------------------------------------

def run_day(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver: Optional[Callable] = None,
    solver_state0: Any = None,
    engine: str = "scan",
    routed: bool = False,
) -> Dict[str, Any]:
    """Run one technique through a day; returns per-epoch + total metrics.

    ``engine="scan"`` compiles the whole day into one call; ``"loop"`` is
    the reference Python hour-loop. A prebuilt ``solver`` closure forces the
    loop engine (the closure may carry state across calls/runs);
    ``solver_state0`` injects initial solver state into the scan engine.
    ``routed`` plays the (S, I, D) routing game in either engine; with the
    degenerate S = 1 origin it reproduces the unrouted numbers bit-for-bit.
    """
    if engine not in ("scan", "loop"):
        raise ValueError(f"unknown engine {engine!r}; known: scan, loop")
    if solver is None and engine == "scan":
        return run_day_scan(env, technique, objective, seed=seed, hours=hours,
                            pretrain=pretrain, peak_state0=peak_state0,
                            cfg_override=cfg_override, solver_state0=solver_state0,
                            routed=routed)
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    if solver is None:
        solver = get_scheduler(
            technique, env, objective,
            pretrain_key=kp if (technique == "gt-drl" and pretrain) else None,
            routed=routed,
            **({"cfg": cfg_override} if cfg_override is not None else {}),
        )
    d = E.num_dcs(env)
    peak = peak_state0 if peak_state0 is not None else jnp.zeros((d,))
    epoch_metrics: List[Dict[str, jnp.ndarray]] = []
    for tau in range(hours):
        key, ks = jax.random.split(key)
        ctx = GameContext(env=env, tau=jnp.int32(tau), objective=objective,
                          routed=routed)
        res = solver(ks, ctx, peak)
        ar = fractions_to_ar(ctx, res.fractions)
        peak, m = E.step_epoch(env, peak, ar, jnp.int32(tau))
        epoch_metrics.append(m)  # stays on device; no per-epoch host sync
    per_epoch: List[Dict[str, float]] = []
    totals = {k: 0.0 for k in _TOTAL_KEYS}
    for tau, m in enumerate(jax.device_get(epoch_metrics)):  # ONE transfer
        row = {k: float(v) for k, v in m.items()}
        row["tau"] = tau
        per_epoch.append(row)
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals, "technique": technique,
            "objective": objective}


def _stats(vals, curves) -> Dict[str, Any]:
    """mean ± stderr of daily totals + the mean per-epoch curve."""
    vals = np.asarray(vals, dtype=float)
    curves = np.asarray(curves, dtype=float)
    n = vals.shape[0]
    return {
        "mean": float(vals.mean()),
        "stderr": float(vals.std(ddof=1) / np.sqrt(n)) if n > 1 else 0.0,
        "curve_mean": curves.mean(axis=0).tolist(),
    }


def compare_techniques(
    envs,
    techniques=TECHNIQUES,
    objective: str = "carbon",
    *,
    hours: int = 24,
    seed0: int = 0,
    engine: str = "batched",
    cfg_overrides: Optional[Dict[str, Any]] = None,
    routed: bool = False,
) -> Dict[str, Dict[str, Any]]:
    """The paper's protocol: several runs (one env per resampled arrival
    pattern), mean±stderr of daily totals. The ranked metric is daily carbon
    under ``objective="carbon"`` and daily total cost otherwise (``cost_usd``
    already includes the SLA-miss charge, so ``objective="cost_sla"`` ranks
    on the latency-priced bill).

    ``engine="batched"`` (default) drives ``run_days_batched`` once per
    technique — the whole env suite is one vmapped compile, with GT-DRL
    agents pretrained once (deploy-once, on ``PRNGKey(seed0 + 999)``) and
    broadcast through the scan carry. ``engine="loop"`` is the hour-loop
    parity reference with identical deploy-once semantics: each day starts
    from the same deployed agent snapshot, so both engines agree within
    float32 tolerance. (The seed implementation instead shared one stateful
    scheduler across days — agents kept adapting online, which cannot vmap;
    per-day reset from the deployed snapshot is the protocol now, in both
    engines.) ``cfg_overrides`` maps technique -> config.
    """
    if isinstance(envs, E.EnvParams):
        envs = [envs]
    envs = list(envs)
    if engine not in ("batched", "loop"):
        raise ValueError(f"unknown engine {engine!r}; known: batched, loop")
    overrides = dict(cfg_overrides or {})
    metric = "carbon_kg" if objective == "carbon" else "cost_usd"
    seeds = [seed0 + r for r in range(len(envs))]
    out: Dict[str, Dict[str, Any]] = {}

    def deployed_agents(cfg):
        c = cfg or gt_drl.GTDRLConfig()
        return gt_drl.pretrain(jax.random.PRNGKey(seed0 + 999), envs[0],
                               objective, c, routed)

    if engine == "loop":
        for t in techniques:
            cfg = overrides.get(t)
            agents0 = deployed_agents(cfg) if t == "gt-drl" else None
            solver = None if t == "gt-drl" else get_scheduler(
                t, envs[0], objective,
                **({"cfg": cfg} if cfg is not None else {}))
            vals, curves = [], []
            for r, env in enumerate(envs):
                s = (GTDRLScheduler(env, objective, cfg, agents=agents0).solve_epoch
                     if t == "gt-drl" else solver)
                res = run_day(env, t, objective, seed=seeds[r], hours=hours,
                              solver=s, engine="loop", routed=routed)
                vals.append(res["totals"][metric])
                curves.append([e[metric] for e in res["per_epoch"]])
            out[t] = _stats(vals, curves)
        return out

    env_b = E.stack_envs(envs)
    for t in techniques:
        cfg = overrides.get(t)
        state0 = deployed_agents(cfg) if t == "gt-drl" else None
        res = run_days_batched(env_b, t, objective, seeds=seeds, hours=hours,
                               cfg_override=cfg, solver_state0=state0,
                               routed=routed)
        out[t] = _stats(res["totals"][metric], res["per_epoch"][metric])
    return out
