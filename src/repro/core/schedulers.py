"""Unified scheduler registry + the 24-epoch day simulation harness.

Every technique exposes ``solve_epoch(key, ctx, peak_state) -> SolveResult``;
``run_day`` drives any of them through the paper's experimental protocol:
24 one-hour epochs, monthly peak-demand state threaded through, metrics
from the *detailed* simulator (not the optimization estimate).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dcsim import env as E
from . import ddpg, force_directed, genetic, gt_drl, nash, ppo_joint
from .game import GameContext, SolveResult, capacity_fractions, fractions_to_ar

TECHNIQUES = ("fd", "ga", "nash", "ddpg", "ppo", "gt-drl")


class GTDRLScheduler:
    """Stateful wrapper: holds (pre)trained per-player agents across epochs."""

    def __init__(self, env: E.EnvParams, objective: str, cfg: Optional[gt_drl.GTDRLConfig] = None,
                 pretrain_key=None):
        self.cfg = cfg or gt_drl.GTDRLConfig()
        self.objective = objective
        if pretrain_key is not None:
            self.agents = gt_drl.pretrain(pretrain_key, env, objective, self.cfg)
        else:
            self.agents = gt_drl.init_agents(jax.random.PRNGKey(0), env, self.cfg)
        self._solve = jax.jit(
            lambda key, agents, ctx, peak: gt_drl.solve_epoch(key, agents, ctx, peak, self.cfg)
        )

    def solve_epoch(self, key, ctx: GameContext, peak_state) -> SolveResult:
        self.agents, res = self._solve(key, self.agents, ctx, peak_state)
        return res


def get_scheduler(name: str, env: E.EnvParams, objective: str,
                  pretrain_key=None, **overrides) -> Callable:
    """Returns solve_epoch(key, ctx, peak_state) -> SolveResult, jitted so a
    24-epoch day compiles once (GameContext is a pytree; tau is traced)."""
    mods = {"fd": (force_directed, force_directed.FDConfig()),
            "ga": (genetic, genetic.GAConfig()),
            "nash": (nash, nash.NashConfig()),
            "ddpg": (ddpg, ddpg.DDPGConfig()),
            "ppo": (ppo_joint, ppo_joint.JointPPOConfig())}
    if name in mods:
        mod, default_cfg = mods[name]
        cfg = overrides.get("cfg", default_cfg)
        return jax.jit(functools.partial(mod.solve_epoch, cfg=cfg))
    if name == "gt-drl":
        sched = GTDRLScheduler(env, objective, overrides.get("cfg"), pretrain_key)
        return sched.solve_epoch
    raise KeyError(f"unknown technique {name!r}; known: {TECHNIQUES}")


def run_day(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver: Optional[Callable] = None,
) -> Dict[str, Any]:
    """Run one technique through a day; returns per-epoch + total metrics."""
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    if solver is None:
        solver = get_scheduler(
            technique, env, objective,
            pretrain_key=kp if (technique == "gt-drl" and pretrain) else None,
            **({"cfg": cfg_override} if cfg_override is not None else {}),
        )
    d = E.num_dcs(env)
    peak = peak_state0 if peak_state0 is not None else jnp.zeros((d,))
    per_epoch: List[Dict[str, float]] = []
    totals = {"carbon_kg": 0.0, "cost_usd": 0.0, "violation": 0.0}
    for tau in range(hours):
        key, ks = jax.random.split(key)
        ctx = GameContext(env=env, tau=jnp.int32(tau), objective=objective)
        res = solver(ks, ctx, peak)
        ar = fractions_to_ar(ctx, res.fractions)
        peak, m = E.step_epoch(env, peak, ar, jnp.int32(tau))
        row = {k: float(v) for k, v in m.items()}
        row["tau"] = tau
        per_epoch.append(row)
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals, "technique": technique,
            "objective": objective}


def compare_techniques(
    envs,
    techniques=TECHNIQUES,
    objective: str = "carbon",
    *,
    hours: int = 24,
    seed0: int = 0,
) -> Dict[str, Dict[str, Any]]:
    """The paper's protocol: several runs (one env per resampled arrival
    pattern), mean±stderr of daily totals. GT-DRL agents pretrain once on the
    first env and are reused across runs (deploy-once semantics)."""
    import numpy as np

    if isinstance(envs, E.EnvParams):
        envs = [envs]
    out: Dict[str, Dict[str, Any]] = {}
    metric = "carbon_kg" if objective == "carbon" else "cost_usd"
    for t in techniques:
        solver = get_scheduler(
            t, envs[0], objective,
            pretrain_key=jax.random.PRNGKey(seed0 + 999) if t == "gt-drl" else None)
        vals = []
        curves = []
        for r, env in enumerate(envs):
            res = run_day(env, t, objective, seed=seed0 + r, hours=hours, solver=solver)
            vals.append(res["totals"][metric])
            curves.append([e[metric] for e in res["per_epoch"]])
        vals = np.asarray(vals)
        out[t] = {
            "mean": float(vals.mean()),
            "stderr": float(vals.std(ddof=1) / np.sqrt(len(vals))) if len(envs) > 1 else 0.0,
            "curve_mean": np.asarray(curves).mean(axis=0).tolist(),
        }
    return out
