"""Unified scheduler registry + the compiled day-simulation engine.

Every technique exposes ``solve_epoch(key, ctx, peak_state) -> SolveResult``;
``run_day`` drives any of them through the paper's experimental protocol:
24 one-hour epochs, monthly peak-demand state threaded through, metrics
from the *detailed* simulator (not the optimization estimate).

Two engines share that protocol:

- ``engine="scan"`` (default): the whole day is ONE jitted call — a
  ``lax.scan`` over epochs with (rng key, peak state, solver state) in the
  carry. Because the day is a single pure function of ``(env, key, peak0,
  state0)``, it vmaps across environments: ``run_days_batched`` evaluates a
  whole scenario suite × seeds fleet (``repro.scenarios``) in one compile.
- ``engine="loop"``: the seed Python hour-loop, kept as the reference
  implementation (and used automatically when a prebuilt stateful
  ``solver`` closure is passed, as ``compare_techniques`` does for
  deploy-once GT-DRL semantics). Both engines produce matching metrics for
  the same technique/seed.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dcsim import env as E
from . import ddpg, force_directed, genetic, gt_drl, nash, ppo_joint
from .game import GameContext, SolveResult, fractions_to_ar

TECHNIQUES = ("fd", "ga", "nash", "ddpg", "ppo", "gt-drl")

_MODS = {"fd": (force_directed, force_directed.FDConfig()),
         "ga": (genetic, genetic.GAConfig()),
         "nash": (nash, nash.NashConfig()),
         "ddpg": (ddpg, ddpg.DDPGConfig()),
         "ppo": (ppo_joint, ppo_joint.JointPPOConfig())}

_TOTAL_KEYS = ("carbon_kg", "cost_usd", "violation")


class GTDRLScheduler:
    """Stateful wrapper: holds (pre)trained per-player agents across epochs."""

    def __init__(self, env: E.EnvParams, objective: str, cfg: Optional[gt_drl.GTDRLConfig] = None,
                 pretrain_key=None):
        self.cfg = cfg or gt_drl.GTDRLConfig()
        self.objective = objective
        if pretrain_key is not None:
            self.agents = gt_drl.pretrain(pretrain_key, env, objective, self.cfg)
        else:
            self.agents = gt_drl.init_agents(jax.random.PRNGKey(0), env, self.cfg)
        self._solve = jax.jit(
            lambda key, agents, ctx, peak: gt_drl.solve_epoch(key, agents, ctx, peak, self.cfg)
        )

    def solve_epoch(self, key, ctx: GameContext, peak_state) -> SolveResult:
        self.agents, res = self._solve(key, self.agents, ctx, peak_state)
        return res


def get_scheduler(name: str, env: E.EnvParams, objective: str,
                  pretrain_key=None, **overrides) -> Callable:
    """Returns solve_epoch(key, ctx, peak_state) -> SolveResult, jitted so a
    24-epoch day compiles once (GameContext is a pytree; tau is traced)."""
    if name in _MODS:
        mod, default_cfg = _MODS[name]
        cfg = overrides.get("cfg", default_cfg)
        return jax.jit(functools.partial(mod.solve_epoch, cfg=cfg))
    if name == "gt-drl":
        sched = GTDRLScheduler(env, objective, overrides.get("cfg"), pretrain_key)
        return sched.solve_epoch
    raise KeyError(f"unknown technique {name!r}; known: {TECHNIQUES}")


# ---------------------------------------------------------------------------
# compiled day engine: one lax.scan over epochs == one jitted call per day
# ---------------------------------------------------------------------------

def _solver_step(technique: str, cfg) -> Callable:
    """step(key, state, ctx, peak) -> (state, SolveResult); state threads the
    scan carry (per-player agents for gt-drl, () for stateless solvers)."""
    if technique == "gt-drl":
        cfg = cfg or gt_drl.GTDRLConfig()

        def step(key, agents, ctx, peak):
            return gt_drl.solve_epoch(key, agents, ctx, peak, cfg)
        return step
    if technique not in _MODS:
        raise KeyError(f"unknown technique {technique!r}; known: {TECHNIQUES}")
    mod, default_cfg = _MODS[technique]
    cfg = cfg or default_cfg

    def step(key, state, ctx, peak):
        return state, mod.solve_epoch(key, ctx, peak, cfg=cfg)
    return step


@functools.lru_cache(maxsize=None)
def _day_core(technique: str, objective: str, hours: int, cfg) -> Callable:
    """day(env, key, peak0, state0) -> (peak, state, metrics (hours,)-dict).

    Pure and jit/vmap-friendly; the RNG key is split exactly as the
    reference loop does, so both engines see the same per-epoch keys.
    """
    step = _solver_step(technique, cfg)

    def day(env: E.EnvParams, key, peak0, state0):
        def body(carry, tau):
            key, peak, state = carry
            key, ks = jax.random.split(key)
            ctx = GameContext(env=env, tau=tau, objective=objective)
            state, res = step(ks, state, ctx, peak)
            ar = fractions_to_ar(ctx, res.fractions)
            peak, m = E.step_epoch(env, peak, ar, tau)
            return (key, peak, state), m

        (_, peak, state), ms = jax.lax.scan(
            body, (key, peak0, state0), jnp.arange(hours, dtype=jnp.int32))
        return peak, state, ms

    return day


@functools.lru_cache(maxsize=None)
def _compiled_day(technique: str, objective: str, hours: int, cfg) -> Callable:
    return jax.jit(_day_core(technique, objective, hours, cfg))


@functools.lru_cache(maxsize=None)
def _compiled_batch(technique: str, objective: str, hours: int, cfg) -> Callable:
    """One compile for a whole fleet: vmap the day core over (env, key)."""
    core = _day_core(technique, objective, hours, cfg)
    return jax.jit(jax.vmap(core, in_axes=(0, 0, None, None)))


def _day_inputs(env, technique, objective, seed, pretrain, cfg):
    """Replicates the reference loop's key discipline + initial solver state."""
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    if technique == "gt-drl":
        c = cfg or gt_drl.GTDRLConfig()
        state0 = (gt_drl.pretrain(kp, env, objective, c) if pretrain
                  else gt_drl.init_agents(jax.random.PRNGKey(0), env, c))
    else:
        state0 = ()
    return key, state0


def _format_day(ms, hours: int, technique: str, objective: str) -> Dict[str, Any]:
    """Stacked (hours,) metric arrays -> the run_day result dict."""
    host = {k: np.asarray(v).astype(float).tolist() for k, v in ms.items()}
    per_epoch = [{**{k: host[k][t] for k in host}, "tau": t} for t in range(hours)]
    totals = {k: 0.0 for k in _TOTAL_KEYS}
    for row in per_epoch:
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals, "technique": technique,
            "objective": objective}


def run_day_scan(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
) -> Dict[str, Any]:
    """One technique through a day as a single jitted lax.scan call."""
    key, state0 = _day_inputs(env, technique, objective, seed, pretrain, cfg_override)
    peak0 = peak_state0 if peak_state0 is not None else jnp.zeros((E.num_dcs(env),))
    day = _compiled_day(technique, objective, hours, cfg_override)
    _, _, ms = day(env, key, peak0, state0)
    return _format_day(ms, hours, technique, objective)


def stack_envs(envs: Sequence[E.EnvParams]) -> E.EnvParams:
    """Stack same-shape envs leaf-wise into one batched EnvParams."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *envs)


def run_days_batched(
    envs,
    technique: str,
    objective: str = "carbon",
    *,
    seeds: Optional[Sequence[int]] = None,
    hours: int = 24,
    pretrain: bool = True,
    cfg_override: Any = None,
) -> Dict[str, Any]:
    """Evaluate a fleet of scenario-days in ONE compiled vmapped call.

    ``envs``: a list of same-shape EnvParams (e.g. a materialized scenario
    suite) or an already-stacked batched EnvParams. ``seeds`` defaults to
    ``range(n)`` — one RNG stream per day, split exactly like ``run_day``.
    GT-DRL pretrains once (deploy-once) and the agents are broadcast.

    Returns ``{"totals": {k: (n,)}, "per_epoch": {k: (n, hours)}}`` numpy
    arrays plus bookkeeping fields.
    """
    if isinstance(envs, E.EnvParams) and envs.er.ndim == 2:
        envs = [envs]  # single env == batch of one (compare_techniques parity)
    if isinstance(envs, E.EnvParams):
        env_b, n = envs, int(envs.er.shape[0])
        env0 = jax.tree_util.tree_map(lambda x: x[0], envs)
    else:
        envs = list(envs)
        env_b, n = stack_envs(envs), len(envs)
        env0 = envs[0]
    seeds = list(range(n)) if seeds is None else list(seeds)
    if len(seeds) != n:
        raise ValueError(f"{len(seeds)} seeds for {n} scenario-days")

    # per-day keys split exactly as run_day splits them; gt-drl pretrains
    # ONCE on the first seed's pretrain key (deploy-once semantics)
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(s))[1] for s in seeds])
    _, state0 = _day_inputs(env0, technique, objective, seeds[0], pretrain,
                            cfg_override)
    peak0 = jnp.zeros((E.num_dcs(env0),))

    batch = _compiled_batch(technique, objective, hours, cfg_override)
    _, _, ms = batch(env_b, keys, peak0, state0)
    out = {k: np.asarray(v) for k, v in ms.items()}  # (n, hours) each
    totals = {k: out[k].sum(axis=1) for k in _TOTAL_KEYS}
    return {"totals": totals, "per_epoch": out, "technique": technique,
            "objective": objective, "seeds": seeds}


# ---------------------------------------------------------------------------
# day protocol entry points
# ---------------------------------------------------------------------------

def run_day(
    env: E.EnvParams,
    technique: str,
    objective: str = "carbon",
    *,
    seed: int = 0,
    hours: int = 24,
    pretrain: bool = True,
    peak_state0: Optional[jnp.ndarray] = None,
    cfg_override: Any = None,
    solver: Optional[Callable] = None,
    engine: str = "scan",
) -> Dict[str, Any]:
    """Run one technique through a day; returns per-epoch + total metrics.

    ``engine="scan"`` compiles the whole day into one call; ``"loop"`` is
    the reference Python hour-loop. A prebuilt ``solver`` closure forces the
    loop engine (the closure may carry state across calls/runs).
    """
    if engine not in ("scan", "loop"):
        raise ValueError(f"unknown engine {engine!r}; known: scan, loop")
    if solver is None and engine == "scan":
        return run_day_scan(env, technique, objective, seed=seed, hours=hours,
                            pretrain=pretrain, peak_state0=peak_state0,
                            cfg_override=cfg_override)
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    if solver is None:
        solver = get_scheduler(
            technique, env, objective,
            pretrain_key=kp if (technique == "gt-drl" and pretrain) else None,
            **({"cfg": cfg_override} if cfg_override is not None else {}),
        )
    d = E.num_dcs(env)
    peak = peak_state0 if peak_state0 is not None else jnp.zeros((d,))
    per_epoch: List[Dict[str, float]] = []
    totals = {k: 0.0 for k in _TOTAL_KEYS}
    for tau in range(hours):
        key, ks = jax.random.split(key)
        ctx = GameContext(env=env, tau=jnp.int32(tau), objective=objective)
        res = solver(ks, ctx, peak)
        ar = fractions_to_ar(ctx, res.fractions)
        peak, m = E.step_epoch(env, peak, ar, jnp.int32(tau))
        row = {k: float(v) for k, v in m.items()}
        row["tau"] = tau
        per_epoch.append(row)
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals, "technique": technique,
            "objective": objective}


def compare_techniques(
    envs,
    techniques=TECHNIQUES,
    objective: str = "carbon",
    *,
    hours: int = 24,
    seed0: int = 0,
) -> Dict[str, Dict[str, Any]]:
    """The paper's protocol: several runs (one env per resampled arrival
    pattern), mean±stderr of daily totals. GT-DRL agents pretrain once on the
    first env and are reused across runs (deploy-once semantics)."""
    if isinstance(envs, E.EnvParams):
        envs = [envs]
    out: Dict[str, Dict[str, Any]] = {}
    metric = "carbon_kg" if objective == "carbon" else "cost_usd"
    for t in techniques:
        solver = get_scheduler(
            t, envs[0], objective,
            pretrain_key=jax.random.PRNGKey(seed0 + 999) if t == "gt-drl" else None)
        vals = []
        curves = []
        for r, env in enumerate(envs):
            res = run_day(env, t, objective, seed=seed0 + r, hours=hours, solver=solver)
            vals.append(res["totals"][metric])
            curves.append([e[metric] for e in res["per_epoch"]])
        vals = np.asarray(vals)
        out[t] = {
            "mean": float(vals.mean()),
            "stderr": float(vals.std(ddof=1) / np.sqrt(len(vals))) if len(envs) > 1 else 0.0,
            "curve_mean": np.asarray(curves).mean(axis=0).tolist(),
        }
    return out
