"""GA: Genitor-style steady-state genetic algorithm (technique (b), [16]).

Population of strategy matrices; rank-based parent selection (Genitor [44]),
row-wise arithmetic crossover, Dirichlet mutation, replace-worst. The
iteration budget models the paper's one-hour wall-clock cap: it is *fixed*
per problem size, so quality degrades as |I|·|D| grows — exactly the
instability the paper reports for GA at 8/16 DCs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .game import GameContext, SolveResult, cloud_objective, uniform_fractions


@dataclasses.dataclass(frozen=True)
class GAConfig:
    population: int = 32
    generations: int = 150   # fixed budget ≈ the paper's 1-hour cap
    mutate_prob: float = 0.3
    mutate_conc: float = 25.0  # Dirichlet concentration (higher = smaller step)


def solve_epoch(key, ctx: GameContext, peak_state: jnp.ndarray,
                cfg: GAConfig = GAConfig()) -> SolveResult:
    i_n = ctx.num_players()
    joint = ctx.joint_shape()  # (I, D), or (S, I, D) for routed games

    def obj(f):
        return cloud_objective(ctx, f, peak_state)

    k0, key = jax.random.split(key)
    f0 = uniform_fractions(ctx)
    pop = jax.random.dirichlet(k0, jnp.ones((cfg.population,) + joint))
    pop = pop.at[0].set(f0)  # seed with the neutral uniform split
    fit = jax.vmap(obj)(pop)

    def gen(carry, key_g):
        pop, fit = carry
        k1, k2, k3, k4 = jax.random.split(key_g, 4)
        # Genitor rank-based selection: linear bias toward better ranks
        order = jnp.argsort(fit)  # ascending (minimization)
        ranks = jnp.argsort(order)
        p_sel = (cfg.population - ranks).astype(jnp.float32)
        p_sel = p_sel / jnp.sum(p_sel)
        pa = jax.random.choice(k1, cfg.population, p=p_sel)
        pb = jax.random.choice(k2, cfg.population, p=p_sel)
        # player-wise arithmetic crossover ((I, 1) broadcasts over the source
        # axis of a routed (S, I, D) joint: a player's whole routing matrix
        # crosses over as one gene)
        mix = jax.random.uniform(k3, (i_n, 1))
        child = mix * pop[pa] + (1 - mix) * pop[pb]
        # Dirichlet mutation on a random subset of players
        mut = jax.random.dirichlet(k4, child * cfg.mutate_conc + 0.3)
        do_mut = jax.random.uniform(jax.random.fold_in(k4, 1), (i_n, 1)) < cfg.mutate_prob
        child = jnp.where(do_mut, mut, child)
        child = child / jnp.sum(child, axis=-1, keepdims=True)
        cv = obj(child)
        # replace worst
        worst = jnp.argmax(fit)
        better = cv < fit[worst]
        pop = pop.at[worst].set(jnp.where(better, child, pop[worst]))
        fit = fit.at[worst].set(jnp.where(better, cv, fit[worst]))
        return (pop, fit), jnp.min(fit)

    (pop, fit), hist = jax.lax.scan(gen, (pop, fit), jax.random.split(key, cfg.generations))
    best = pop[jnp.argmin(fit)]
    return SolveResult(best, {"history": hist, "best": jnp.min(fit)})
