"""GT-DRL: the paper's contribution (§5.3).

Per-player PPO agents embedded in the non-cooperative game: each round,
every player best-responds with a few PPO iterations against the others'
current strategies (Jacobi-style simultaneous best response — fully
vmappable across players, which is how all |I| agents train on one
accelerator at once), then strategies are re-combined. The game-theoretic
decomposition shrinks each agent's state/action space from |I|·|D| to |D|
(paper §5.3, the central scalability argument).

State faithful to the paper: the player's own strategy (its fractions).
``state_mode="env"`` (beyond-paper, flag-gated) appends normalized per-DC
context features so the pretrained policy can condition on prices/carbon.

Routed games (``GameContext.routed``) grow each player's strategy from a
(D,) simplex row to an (S, D) routing matrix — the decomposition argument
carries over: |S|·|D| per agent instead of |S|·|I|·|D| joint — and
``state_mode="env"`` gains the player's origin-weighted access RTT feature.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..dcsim import env as E
from . import networks as nets
from .game import GameContext, SolveResult, player_rewards, uniform_fractions
from .ppo import AgentState, PPOConfig, agent_init, average_agents, ppo_improve


@dataclasses.dataclass(frozen=True)
class GTDRLConfig:
    ppo: PPOConfig = PPOConfig(horizon=6, episodes=32, iters=4, update_epochs=4)
    rounds: int = 8                 # best-response (game) rounds per epoch
    polish_steps: int = 40          # best-reply refinement of adopted proposals
    polish_lr: float = 0.4
    damping: float = 0.5            # Jacobi damping: blend of new vs old joint
    state_mode: str = "strategy"    # strategy | env
    pretrain_iters: int = 60        # total (tau, joint) contexts seen offline
    pretrain_batch: int = 4         # contexts trained in parallel per step
    half_update: str = "gather"     # gather (I/2 dispatch) | masked (reference)


def _norm(x: jnp.ndarray) -> jnp.ndarray:
    """Max-normalize; safe when the whole vector is zero (e.g. a zero-carbon
    grid or a renewable_drought scale=0 scenario) — returns zeros, not NaN."""
    return x / jnp.maximum(jnp.max(jnp.abs(x)), 1e-9)


def _ctx_features(env: E.EnvParams, tau, i, routed: bool = False) -> jnp.ndarray:
    """Per-DC context for state_mode='env' (beyond-paper).

    Routed games append the player's origin-weighted access RTT per DC —
    the locality signal the (S, D) routing strategy is meant to exploit.
    """
    feats = [
        _norm(env.er[i]),
        _norm(E.dp_max_t(env, tau)),
        _norm(env.carbon[:, tau]),
        _norm(env.eprice[:, tau]),
        _norm(env.rp[:, tau]),
    ]
    if routed:
        w = E.origin_at(env, tau)[:, i]                       # (S,)
        feats.append(_norm(jnp.sum(w[:, None] * E.source_rtt(env), axis=0)))
    return jnp.concatenate(feats)


def _row_shape(env: E.EnvParams, routed: bool):
    """One player's strategy shape: (D,), or (S, D) in a routed game.

    The degenerate S = 1 origin is normalized to the unrouted (D,) shape —
    one source has nothing to route, and running the identical program is
    what keeps the S = 1 parity guarantee bit-for-bit (see
    ``GameContext.is_routed``).
    """
    d = E.num_dcs(env)
    s = E.num_sources(env)
    return (s, d) if (routed and s > 1) else (d,)


def state_dim(env: E.EnvParams, mode: str, routed: bool = False) -> int:
    d = E.num_dcs(env)
    shape = _row_shape(env, routed)
    own = int(np.prod(shape))
    if mode == "strategy":
        return own
    return own + (6 if len(shape) == 2 else 5) * d


def _state_of(env, tau, i, mode, routed):
    shape = _row_shape(env, routed)

    def fn(logits):
        frac = jax.nn.softmax(logits.reshape(shape), axis=-1).reshape(-1)
        if mode == "strategy":
            return frac
        return jnp.concatenate([frac, _ctx_features(env, tau, i, routed)])
    return fn


def init_agents(key, env: E.EnvParams, cfg: GTDRLConfig,
                routed: bool = False) -> AgentState:
    """Stacked per-player agents: leading axis |I| on every leaf.

    In a routed game each agent's action space is the flattened (S, D)
    routing matrix instead of a single (D,) simplex row.
    """
    i_n = E.num_players(env)
    sd = state_dim(env, cfg.state_mode, routed)
    ad = int(np.prod(_row_shape(env, routed)))
    keys = jax.random.split(key, i_n)
    return jax.vmap(lambda k: agent_init(k, sd, ad, cfg.ppo))(keys)


def _player_reward_closure(env, tau, objective, peak_state, joint_fracs, i, scale):
    """reward(logits) = -objective_i(joint with row i replaced) / scale."""
    routed = joint_fracs.ndim == 3
    shape = _row_shape(env, routed)

    def fn(logits):
        row = jax.nn.softmax(logits.reshape(shape), axis=-1)
        fr = joint_fracs.at[..., i, :].set(row)
        ar = (E.project_feasible_routed(env, fr, tau) if routed
              else E.project_feasible(env, fr, tau))
        r = E.player_reward(env, ar, tau, peak_state, objective)[i]
        return -r / scale

    return fn


def _one_player_round(key, agent, env, tau, objective, peak_state, joint, i, mode, ppo_cfg,
                      polish_steps=30, polish_lr=0.4):
    """PPO-improve player i against fixed others; return (agent, greedy row).

    The player's strategy row is (D,) — or its (S, D) routing matrix in a
    routed game (``joint`` is then the (S, I, D) tensor); the agent always
    works in the flattened logit space and rows reshape at the boundary.
    """
    routed = joint.ndim == 3
    shape = _row_shape(env, routed)
    proj = E.project_feasible_routed if routed else E.project_feasible
    base = jnp.abs(E.player_reward(
        env, proj(env, joint, tau), tau, peak_state, objective)[i]) + 1e-6
    reward_of = _player_reward_closure(env, tau, objective, peak_state, joint, i, base)
    state_of = _state_of(env, tau, i, mode, routed)
    own_logits = jnp.log(joint[..., i, :] + 1e-9).reshape(-1)

    def state0_fn(k):
        # start episodes around the current strategy with Dirichlet jitter
        alpha = joint[..., i, :] * 20.0 + 0.5
        fr = jax.random.dirichlet(
            k, jnp.broadcast_to(alpha, (ppo_cfg.episodes,) + alpha.shape))
        fr = fr.reshape(ppo_cfg.episodes, -1)
        if mode == "strategy":
            return fr
        ctxf = _ctx_features(env, tau, i, routed)
        return jnp.concatenate([fr, jnp.broadcast_to(ctxf, (ppo_cfg.episodes, ctxf.shape[0]))], axis=1)

    k_ppo, k_cand = jax.random.split(key)
    agent, info = ppo_improve(k_ppo, agent, state0_fn, state_of, reward_of, ppo_cfg)
    obs.tap("gt_drl/ppo", {"player": i, "actor_loss": info["actor_loss"],
                           "mean_reward": info["mean_reward"]})
    # Best response over the learned policy's support: the stochastic policy
    # proposes candidates (greedy mean + samples), the player adopts whichever
    # proposal minimizes its own objective, never regressing below its current
    # row. This is the game-theoretic step; PPO supplies the proposal
    # distribution (paper §5.3: "the agent determines the optimal strategy").
    state_now = state_of(own_logits)
    mu = nets.actor_mean(agent.actor, state_now)
    std = jnp.exp(jnp.clip(agent.actor["log_std"], -4.0, 1.0))
    n_cand = 16
    eps = jax.random.normal(k_cand, (n_cand,) + mu.shape)
    cand_logits = jnp.concatenate(
        [mu[None], own_logits[None], mu[None] + std * eps], axis=0)
    rewards = jax.vmap(reward_of)(cand_logits)
    best_logits = cand_logits[jnp.argmax(rewards)]
    # ... then the game's rapid best-reply refinement polishes BOTH the
    # policy's best proposal and the incumbent row, adopting whichever basin
    # wins (paper: GT-DRL "combin[es] the rapidness of a non-cooperative
    # optimization strategy with the exploration abilities of DRL"). Polishing
    # the incumbent too means a player's step never does worse than a pure
    # best-reply step — exploration can only help, never commit to a worse
    # basin.
    def polish(logits, _):
        g = jax.grad(lambda lg: -reward_of(lg))(logits)
        return logits - polish_lr * g / (jnp.linalg.norm(g) + 1e-9), None

    def run_polish(logits0):
        out, _ = jax.lax.scan(polish, logits0, None, length=polish_steps)
        return out

    starts = jnp.stack([best_logits, own_logits])
    polished = jax.vmap(run_polish)(starts)
    finals = jnp.concatenate([polished, starts], axis=0)
    final_rewards = jax.vmap(reward_of)(finals)
    row = jax.nn.softmax(finals[jnp.argmax(final_rewards)].reshape(shape), axis=-1)
    return agent, row


def _run_players(keys, agents, idx, env, tau, objective, peak_state, joint, cfg):
    """vmap ``_one_player_round`` over the given player rows.

    ``keys``/``agents`` carry a leading axis matching ``idx``; module-level
    lookup of ``_one_player_round`` keeps the dispatch observable in tests.
    """
    def run(k, a, i):
        return _one_player_round(
            k, a, env=env, tau=tau, objective=objective, peak_state=peak_state,
            joint=joint, i=i, mode=cfg.state_mode, ppo_cfg=cfg.ppo,
            polish_steps=cfg.polish_steps, polish_lr=cfg.polish_lr)

    return jax.vmap(run)(keys, agents, idx)


def half_update(agents, joint, key_r, parity: int, ctx: GameContext,
                peak_state, cfg: GTDRLConfig):
    """Red-black Gauss-Seidel half-step: players with index%2==parity
    best-respond simultaneously (vmapped); the other half hold — sequential
    information flow at Jacobi's vmap efficiency.

    ``cfg.half_update`` selects the implementation:

    - ``"gather"`` (default): gather the active half's rows/agents, dispatch
      ``_one_player_round`` for ceil(I/2) players only, scatter back — half
      the per-round FLOPs of the full-width version.
    - ``"masked"``: reference — dispatch all I players and discard the
      inactive half's updates with a parity mask. Same results (the per-player
      keys are identical), twice the work; kept for parity tests/benchmarks.

    Both modes give each agent ceil(rounds) PPO updates per round. The
    original implementation also trained the *inactive* half's agents each
    half-step (two updates per round, against a stale joint, discarding only
    their rows) — that extra compute is exactly what this restructure
    removes, so gt-drl trajectories differ numerically from the seed commit.
    """
    env = ctx.env
    i_n = E.num_players(env)
    routed = joint.ndim == 3
    keys = jax.random.split(key_r, i_n)
    # vmapped rows arrive player-major ((n,) + row_shape); a routed joint is
    # source-major (S, I, D), so scatters move the player axis back to -2
    to_joint = (lambda rows: jnp.moveaxis(rows, 0, 1)) if routed else (lambda rows: rows)
    if cfg.half_update == "gather":
        idx = jnp.arange(parity, i_n, 2)
        sub = jax.tree_util.tree_map(lambda x: x[idx], agents)
        sub, rows = _run_players(keys[idx], sub, idx, env, ctx.tau,
                                 ctx.objective, peak_state, joint, cfg)
        agents = jax.tree_util.tree_map(
            lambda full, new: full.at[idx].set(new), agents, sub)
        return agents, joint.at[..., idx, :].set(to_joint(rows))
    if cfg.half_update != "masked":
        raise ValueError(f"unknown half_update {cfg.half_update!r}")
    new_agents, rows = _run_players(keys, agents, jnp.arange(i_n), env, ctx.tau,
                                    ctx.objective, peak_state, joint, cfg)
    active = jnp.arange(i_n) % 2 == parity
    agents = jax.tree_util.tree_map(
        lambda old, new: jnp.where(
            active.reshape((i_n,) + (1,) * (new.ndim - 1)), new, old),
        agents, new_agents)
    mask = active[None, :, None] if routed else active[:, None]
    return agents, jnp.where(mask, to_joint(rows), joint)


def solve_epoch(
    key,
    agents: AgentState,
    ctx: GameContext,
    peak_state: jnp.ndarray,
    cfg: GTDRLConfig,
    init_fracs: Optional[jnp.ndarray] = None,
) -> Tuple[AgentState, SolveResult]:
    """Run the game for one epoch: rounds × (red half, black half).

    Each best-response round is divergence-checked: a round whose joint
    strategy or game value goes non-finite (an exploding PPO update) is
    rewound — agents and joint revert to the previous iterate, the round is
    counted in ``info["diverged_rounds"]``, and the game keeps playing from
    the last healthy state instead of poisoning every later round (and the
    epoch's best) with NaNs. Finite trajectories are bit-for-bit unchanged:
    the rewind is a ``jnp.where`` select that always picks the new iterate.
    """
    joint0 = init_fracs if init_fracs is not None else uniform_fractions(ctx)

    def one_round(carry, key_r):
        agents, joint, best_joint, best_val, diverged = carry
        prev_agents, prev_joint = agents, joint
        k1, k2 = jax.random.split(key_r)
        agents, joint = half_update(agents, joint, k1, 0, ctx, peak_state, cfg)
        agents, joint = half_update(agents, joint, k2, 1, ctx, peak_state, cfg)
        val = jnp.sum(player_rewards(ctx, joint, peak_state))
        ok = jnp.all(jnp.isfinite(joint)) & jnp.isfinite(val)
        agents = jax.tree_util.tree_map(
            lambda new, old: jnp.where(ok, new, old), agents, prev_agents)
        joint = jnp.where(ok, joint, prev_joint)
        diverged = diverged + jnp.where(ok, 0, 1).astype(jnp.int32)
        better = ok & (val < best_val)
        best_joint = jnp.where(better, joint, best_joint)
        best_val = jnp.where(better, val, best_val)
        obs.tap("gt_drl/round",
                {"value": val, "best": best_val,
                 "delta": jnp.max(jnp.abs(joint - prev_joint))})
        return (agents, joint, best_joint, best_val, diverged), val

    val0 = jnp.sum(player_rewards(ctx, joint0, peak_state))
    carry0 = (agents, joint0, joint0, val0, jnp.int32(0))
    (agents, joint, best_joint, best_val, diverged), vals = jax.lax.scan(
        one_round, carry0, jax.random.split(key, cfg.rounds))
    return agents, SolveResult(best_joint,
                               {"round_values": vals, "best": best_val,
                                "diverged_rounds": diverged})


def deploy(key, env: E.EnvParams, objective: str,
           cfg: Optional[GTDRLConfig] = None, routed: bool = False,
           pretrain_agents: bool = True) -> AgentState:
    """The deploy-once snapshot the engines thread through their carries.

    ``pretrain_agents=True`` runs offline pretraining on ``key`` (the paper's
    protocol); ``False`` returns fresh agents from the fixed ``PRNGKey(0)``
    init — exactly the two states the engines' key discipline has always
    produced, now reachable by name so the technique registry (and
    ``ExperimentSpec``) can build the carry without special-casing gt-drl.
    """
    cfg = cfg or GTDRLConfig()
    if pretrain_agents:
        return pretrain(key, env, objective, cfg, routed)
    return init_agents(jax.random.PRNGKey(0), env, cfg, routed)


# ---------------------------------------------------------------------------
# offline pretraining (paper §6: random uniformly-sampled arrival rates)
# ---------------------------------------------------------------------------

def pretrain(
    key,
    env: E.EnvParams,
    objective: str,
    cfg: GTDRLConfig,
    routed: bool = False,
) -> AgentState:
    """Offline training over random (tau, arrival-scale, strategy) contexts.

    Contexts are trained ``pretrain_batch`` at a time: each scan step vmaps
    the all-player round over a batch of independently sampled (tau, joint)
    contexts from the same starting agents, then averages the resulting
    parameter/moment trees (parallel-SGD averaging). Total contexts seen is
    ``>= pretrain_iters``; wall-clock shrinks by ~the batch factor since the
    sequential scan is ``pretrain_iters / pretrain_batch`` steps long.
    """
    i_n, d = E.num_players(env), E.num_dcs(env)
    joint_shape = _row_shape(env, routed)[:-1] + (i_n, d)
    agents = init_agents(key, env, cfg, routed)
    peak0 = jnp.zeros((d,))
    batch = max(1, cfg.pretrain_batch)
    steps = -(-cfg.pretrain_iters // batch)  # ceil

    def one_ctx(agents, key_t):
        k1, k2, k3 = jax.random.split(key_t, 3)
        tau = jax.random.randint(k1, (), 0, 24)
        joint = jax.random.dirichlet(k2, jnp.ones(joint_shape))
        keys = jax.random.split(k3, i_n)
        agents, _ = _run_players(keys, agents, jnp.arange(i_n), env, tau,
                                 objective, peak0, joint, cfg)
        return agents

    def one(agents, key_s):
        agents_b = jax.vmap(one_ctx, in_axes=(None, 0))(
            agents, jax.random.split(key_s, batch))
        return average_agents(agents_b), None

    agents, _ = jax.lax.scan(one, agents, jax.random.split(key, steps))
    return agents
