"""Public API of the evaluation system (the paper's primary contribution).

The documented import path::

    from repro.core import ExperimentSpec, run, sweep, compare_techniques

``ExperimentSpec`` declares one evaluation (technique, objective, engine,
routed, hours/days, seeds, solver cfg, pretrain); ``run(spec, envs)`` drives
it through the spec-keyed compile cache (``shard=True`` device-shards the
batched engine); ``sweep(spec, grid)`` expands severity grids into per-point
curves; ``compare_techniques`` is the paper's table protocol. External
solvers plug in via ``register_technique`` and appear everywhere by name.
"""
from .experiment import ENGINES, ExperimentSpec, run, sweep
from .game import (GameContext, SolveResult, TechniqueDef, get_technique,
                   register_technique, technique_names,
                   unregister_technique)
from .schedulers import (TECHNIQUES, compare_techniques, get_scheduler,
                         run_day, run_days_batched, run_month)

__all__ = [
    "ENGINES", "ExperimentSpec", "run", "sweep",
    "GameContext", "SolveResult", "TechniqueDef", "get_technique",
    "register_technique", "technique_names", "unregister_technique",
    "TECHNIQUES", "compare_techniques", "get_scheduler",
    "run_day", "run_days_batched", "run_month",
]
