"""``ExperimentSpec``: the one declarative front door to every engine.

After three engine PRs the evaluation surface had five entry points
(``run_day``, ``run_day_scan``, ``run_days_batched``, ``run_month``,
``compare_techniques``) that each re-threaded the same ten kwargs and each
maintained their own ``functools.lru_cache`` compile path. This module
replaces that with:

- ``ExperimentSpec`` — a frozen, hashable description of one evaluation
  (technique, objective, engine, routed, hours/days, seeds, solver cfg,
  pretrain). Its *static* fields — the ones that change the compiled
  program — key a single module-level compile cache, so the scan, batched,
  sharded and month engines all share compiled artifacts no matter which
  call site (or legacy shim) asks for them.
- ``run(spec, envs)`` — the façade. ``spec.engine`` selects the day scan,
  the hour-loop reference, the vmapped fleet engine or the month scan;
  ``shard=True`` additionally shards the batched engine's env axis across
  devices via ``shard_map`` (single-device results are identical, and the
  default ``shard=False`` path is byte-for-byte the PR 2–4 program).
- ``sweep(spec, grid)`` — severity sweeps: a cartesian grid of scenario-
  transform parameters (``wan_degradation`` factors, ``origin_shift``
  weights, ``sla_tighten`` …) expands into one stacked env batch, every
  technique runs through ONE batched compile, and the result is structured
  per-grid-point curves — the routed-vs-source-blind degradation plots come
  out of a single call.

The legacy entry points in ``repro.core.schedulers`` are kept as thin shims
over the spec and remain pinned bit-for-bit against their PR 2–4 outputs;
new code should ``from repro.core import ExperimentSpec, run, sweep``.

Realized faults (PR 7, ``repro.faults``): ``run(spec, envs, faults=trace)``
threads a ``FaultTrace`` into the compiled engines as a *runtime* argument
— solvers plan on the unfaulted env, and each hour the scan body re-projects
the planned allocation against realized capacity (``spec.failover`` policy)
and simulates the epoch on the realized env view, emitting
``unserved_demand``/``failover_moved``/``degraded_sla_cost_usd`` (plus
``fallback_hours`` from the numerical finite-guard). Faultedness joins the
compile key, so ``faults=None`` keeps dispatching the exact pre-fault
artifacts. ``sweep(..., resume_dir=...)`` adds chunked, journaled,
retry-supervised grid execution (see ``repro.faults.resume``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import faults as FL
from .. import obs
from ..dcsim import env as E
from . import game
from . import schedulers as SCH
from .game import GameContext, fractions_to_ar

_TOTAL_KEYS = ("carbon_kg", "cost_usd", "sla_miss_cost_usd", "violation")

# degradation metrics: present (and summed into totals) only on engines
# compiled with faults/guard — the unfaulted metric dicts never carry them,
# which is what keeps the faults=None result dicts bit-identical
_FAULT_KEYS = ("unserved_demand", "failover_moved", "degraded_sla_cost_usd",
               "fallback_hours")

# per-hour physical signals streamed by the "engine/hour" tap
_TAP_HOUR_KEYS = ("carbon_kg", "cost_usd", "sla_miss_cost_usd", "latency_ms",
                  "grid_power_w")

ENGINES = ("scan", "loop", "batched", "month")


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One evaluation, declaratively. Frozen and hashable: the static fields
    (``technique``, ``objective``, ``hours``, ``cfg``, ``routed``) key the
    module compile cache; the rest (seeds, days, pretrain) only select
    runtime inputs.

    ``engine``: ``"scan"`` — one env, one jitted day; ``"loop"`` — the
    Python hour-loop parity reference; ``"batched"`` — a fleet of
    scenario-days in one vmapped compile (optionally device-sharded);
    ``"month"`` — a second-level scan threading the monthly peak across
    days. ``seeds`` (batched) / ``seed`` (everything else) reproduce the
    legacy entry points' RNG discipline exactly.

    ``taps`` opts the spec into telemetry streams (``repro.obs`` tap
    patterns, e.g. ``("engine/hour", "gt_drl/*")``): tapped engines compile
    as *separate* cache entries whose scan bodies stream diagnostics to the
    obs ring buffer; ``None`` defers to the ambient ``obs.taps(...)``
    context (default: everything off, and the taps-off artifacts are
    bit-for-bit the pre-obs programs).

    ``failover`` picks the realized-fault re-projection policy
    (``repro.faults.POLICIES``) — consulted only when ``run`` receives
    ``faults=``, and normalized out of the compile key otherwise, so it is
    free on unfaulted specs. ``guard=True`` compiles the numerical
    finite-guard (fallback to the capacity-proportional baseline +
    ``fallback_hours`` counter) into an *unfaulted* engine too; faulted
    engines always guard.
    """
    technique: str = "fd"
    objective: str = "carbon"
    engine: str = "scan"
    routed: bool = False
    hours: int = 24
    days: Optional[int] = None            # lint: runtime-only(month engine env repeat count: scan length is data, the per-day program is one artifact)
    seed: int = 0                         # lint: runtime-only(PRNG key material is a traced input, never part of the program)
    seeds: Optional[Tuple[int, ...]] = None  # lint: runtime-only(batched engine per-env keys: vmapped runtime input)
    pretrain: bool = True                 # lint: runtime-only(selects the initial solver state passed in at call time; the compiled epoch is identical)
    cfg: Any = None                       # solver config (frozen dataclass)
    taps: Optional[Tuple[str, ...]] = None   # obs tap patterns (None: ambient)
    failover: str = FL.DEFAULT_POLICY     # realized-fault failover policy
    guard: bool = False                   # finite-guard even when unfaulted
    workload: str = "aibench"             # capability layer the envs came from

    def __post_init__(self):
        if not isinstance(self.workload, str):
            raise ValueError(
                "spec.workload is a capability-layer *name* (the envs "
                "already embed the derived numbers; the name only keys the "
                f"compile cache), got {type(self.workload).__name__}")
        if self.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; known: {ENGINES}")
        if self.objective not in E.OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r}; "
                             f"known: {E.OBJECTIVES}")
        if self.failover not in FL.POLICIES:
            raise ValueError(f"unknown failover policy {self.failover!r}; "
                             f"known: {FL.POLICIES}")
        if self.seeds is not None and not isinstance(self.seeds, tuple):
            object.__setattr__(self, "seeds", tuple(self.seeds))
        if self.taps is not None and not isinstance(self.taps, tuple):
            object.__setattr__(self, "taps", tuple(self.taps))

    def replace(self, **changes) -> "ExperimentSpec":
        return dataclasses.replace(self, **changes)

    def static_key(self) -> Tuple[str, str, int, Any, bool, str, bool, str]:
        """The compile-relevant fields, in ``_day_core`` argument order.

        ``workload`` joins the key even though the engines only ever see
        ``EnvParams``: two workloads legitimately differ in the task-type
        count ``I`` (a shape, hence a retrace), and keeping their artifacts
        under distinct keys makes the cache accounting
        (``obs.engine_stat``) attribute compiles to the right workload.
        """
        return (self.technique, self.objective, self.hours, self.cfg,
                self.routed, self.failover, self.guard, self.workload)

    def effective_taps(self) -> frozenset:
        """The tap set this spec's engines compile under: the spec's own
        ``taps`` when given, else the ambient ``obs.taps(...)`` state. Part
        of the compile key, so tapped and untapped artifacts coexist."""
        return (obs.active_taps() if self.taps is None
                else frozenset(self.taps))


# ---------------------------------------------------------------------------
# engine cores (pure, jit/vmap/scan-friendly)
# ---------------------------------------------------------------------------

def _solver_step(technique: str, cfg) -> Callable:
    """step(key, state, ctx, peak) -> (state, SolveResult) from the registry;
    state threads the scan carry (per-player agents for gt-drl, () for
    stateless solvers)."""
    t = game.get_technique(technique)
    cfg = t.resolve_cfg(cfg)
    step = t.step

    def bound(key, state, ctx, peak):
        return step(key, state, ctx, peak, cfg)
    return bound


@functools.lru_cache(maxsize=None)
def _day_core(technique: str, objective: str, hours: int, cfg,
              routed: bool = False, failover: str = FL.DEFAULT_POLICY,
              guard: bool = False, workload: str = "aibench",
              faulted: bool = False,
              taps: frozenset = frozenset()) -> Callable:
    """day(env, key, peak0, state0[, trace]) -> (peak, state, metrics dict).

    Pure and jit/vmap-friendly; the RNG key is split exactly as the
    reference loop does, so both engines see the same per-epoch keys.
    ``routed`` plays the (S, I, D) routing game instead of the (I, D) one.

    ``faulted`` cores take a fifth argument — a ``faults.FaultTrace``
    pytree — and execute every hour through the plan/execute split: the
    solver steps on the unfaulted ``env`` (planning), then
    ``faults.execute_hour`` re-projects its allocation against realized
    capacity (``failover`` policy) and simulates the epoch on the realized
    env view. ``guard`` (implied by ``faulted``) compiles the finite-guard
    on the solver's joint strategy. All three are trace-time flags: the
    default core lowers to exactly the pre-fault program.

    ``taps`` only keys the cache: the ``obs.tap`` calls in the body check
    trace-time enablement themselves (the dispatch wrapper pins the active
    set to this key's ``taps``), so a taps-off core lowers to exactly the
    pre-obs program and a tapped core is a distinct artifact.

    ``workload`` likewise only keys the cache (see ``static_key``): the body
    is workload-agnostic — a derived llm env is just an ``EnvParams`` with a
    different ``I``.
    """
    del workload  # cache-key discriminator only
    step = _solver_step(technique, cfg)
    guard_on = guard or faulted

    def _body(env, trace, carry, tau):
        key, peak, state = carry
        key, ks = jax.random.split(key)
        ctx = GameContext(env=env, tau=tau, objective=objective,
                          routed=routed)
        state, res = step(ks, state, ctx, peak)
        game.tap_nash_residual(ctx, res.fractions, peak)
        fr = res.fractions
        if guard_on:
            fr, fell_back = FL.guard_fractions(env, tau, fr)
        ar = fractions_to_ar(ctx, fr)
        if faulted:
            peak, m = FL.execute_hour(env, trace, peak, ar, tau, failover)
        else:
            peak, m = E.step_epoch(env, peak, ar, tau)
        if guard_on:
            m = {**m, "fallback_hours": fell_back}
        tap_keys = _TAP_HOUR_KEYS + tuple(k for k in _FAULT_KEYS if k in m)
        obs.tap("engine/hour",
                {"tau": tau, **{k: m[k] for k in tap_keys}})
        return (key, peak, state), m

    taus = functools.partial(jnp.arange, dtype=jnp.int32)
    if faulted:
        def day(env: E.EnvParams, key, peak0, state0, trace):
            (_, peak, state), ms = jax.lax.scan(
                functools.partial(_body, env, trace), (key, peak0, state0),
                taus(hours))
            return peak, state, ms
    else:
        def day(env: E.EnvParams, key, peak0, state0):
            (_, peak, state), ms = jax.lax.scan(
                functools.partial(_body, env, None), (key, peak0, state0),
                taus(hours))
            return peak, state, ms

    return day


def _sharded_batch(core: Callable, faulted: bool = False,
                   fault_axis: bool = False) -> Callable:
    """Shard the batched day engine's env axis across all local devices.

    ``shard_map`` over a 1-axis device mesh: env rows and their RNG keys
    split by shard, (peak0, state0) replicated — and the fault trace, when
    present, replicated (one shared day of trouble) or split with the env
    rows (``fault_axis=True``, a per-point stacked trace); each device runs
    the plain vmapped day core on its slice, so a 1-device mesh runs the
    EXACT unsharded program and N devices evaluate N env shards in parallel
    with zero cross-device collectives.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()), ("env",))
    axes = (0, 0, None, None) + (
        ((0 if fault_axis else None),) if faulted else ())
    specs = (P("env"), P("env"), P(), P()) + (
        ((P("env") if fault_axis else P()),) if faulted else ())
    batched = jax.vmap(core, in_axes=axes)
    fn = shard_map(batched, mesh=mesh,
                   in_specs=specs,
                   out_specs=(P("env"), P("env"), P("env")),
                   check_rep=False)
    return jax.jit(fn)


_KINDS = ("day", "batched", "sharded", "month")


@functools.lru_cache(maxsize=None)
def _compiled_raw(kind: str, technique: str, objective: str, hours: int, cfg,
                  routed: bool, failover: str, guard: bool, workload: str,
                  faulted: bool, fault_axis: bool,
                  taps: frozenset) -> Callable:
    """THE compile cache: one jitted artifact per (engine kind, spec static
    fields, failover/guard/faulted flags, workload, tap set), shared by
    ``run``/``sweep`` and every legacy shim — no engine compiles per call
    site anymore. Artifacts come back wrapped in the obs dispatch span
    (per-call timing + trace-time tap pinning).

    ``fault_axis`` (batched/sharded only): the FaultTrace carries a leading
    env-batch axis — one realized day of trouble per env row — instead of
    one trace shared by every row."""
    key = (kind, technique, objective, hours, cfg, routed, failover, guard,
           workload, faulted, fault_axis, taps)
    if fault_axis and kind not in ("batched", "sharded"):
        raise ValueError("a per-point (stacked) FaultTrace only makes sense "
                         "on the batched/sharded engines; the day and month "
                         f"engines take one trace (kind={kind!r})")
    core = _day_core(technique, objective, hours, cfg, routed, failover,
                     guard, workload, faulted, taps)
    if kind == "day":
        fn = jax.jit(core)
    elif kind == "batched":
        axes = (0, 0, None, None) + (
            ((0 if fault_axis else None),) if faulted else ())
        fn = jax.jit(jax.vmap(core, in_axes=axes))
    elif kind == "sharded":
        fn = _sharded_batch(core, faulted, fault_axis)
    elif kind == "month":
        if faulted:
            raise ValueError(
                "the month engine does not take realized faults yet: a "
                "FaultTrace describes one 24h day, and the month scan "
                "threads days through a second-level carry; run faulted "
                "days through the scan/batched engines")
        def month(env_days, keys, peak0, state0):
            def body(carry, x):
                peak, state = carry
                env, key = x
                peak, state, ms = core(env, key, peak, state)
                return (peak, state), (ms, peak)

            (peak, state), (ms, peaks) = jax.lax.scan(
                body, (peak0, state0), (env_days, keys))
            return peak, state, ms, peaks

        fn = jax.jit(month)
    else:
        raise ValueError(f"unknown engine kind {kind!r}; known: {_KINDS}")
    return obs.spans.instrument_dispatch(key, fn)


def _compiled(kind: str, technique: str, objective: str, hours: int, cfg,
              routed: bool, failover: str = FL.DEFAULT_POLICY,
              guard: bool = False, workload: str = "aibench",
              faulted: bool = False, fault_axis: bool = False,
              taps: frozenset = frozenset()) -> Callable:
    """Front door to the compile cache: same artifact as ``_compiled_raw``
    but every lookup/build is accounted in ``obs.cache_stats()``."""
    key = (kind, technique, objective, hours, cfg, routed, failover, guard,
           workload, faulted, fault_axis, taps)
    hit = obs.spans.engine_lookup(key)
    if hit:
        return _compiled_raw(*key)
    t0 = time.perf_counter()
    fn = _compiled_raw(*key)
    obs.spans.note_build(key, time.perf_counter() - t0)
    return fn


# the cache-introspection surface tests rely on (lru semantics preserved)
_compiled.cache_info = _compiled_raw.cache_info


def _engine_key(spec: ExperimentSpec, *, shard: bool = False,
                faulted: bool = False, fault_axis: bool = False) -> tuple:
    """The compile-cache key ``run`` uses for this spec (also the join key
    for ``obs.engine_stat`` / run records).

    ``failover`` is an execute-time policy: on unfaulted lookups it is
    normalized to the default so a spec's policy choice never forks the
    (identical) unfaulted artifact; ``fault_axis`` is likewise normalized
    out of unfaulted keys.
    """
    kind = {"scan": "day", "batched": "sharded" if shard else "batched",
            "month": "month"}.get(spec.engine)
    if kind is None:
        raise ValueError(f"engine {spec.engine!r} is not compiled")
    technique, objective, hours, cfg, routed, failover, guard, workload = \
        spec.static_key()
    if not faulted:
        failover = FL.DEFAULT_POLICY
        fault_axis = False
    return (kind, technique, objective, hours, cfg, routed, failover, guard,
            workload, faulted, fault_axis, spec.effective_taps())


def compiled_engine(spec: ExperimentSpec, *, shard: bool = False,
                    faulted: bool = False, fault_axis: bool = False) -> Callable:
    """The spec's compiled engine (public access to the cache)."""
    return _compiled(*_engine_key(spec, shard=shard, faulted=faulted,
                                  fault_axis=fault_axis))


def _clear_compile_caches() -> None:
    _day_core.cache_clear()
    _compiled_raw.cache_clear()
    obs.spans.note_eviction()


_compiled.cache_clear = _clear_compile_caches


# re-registering a technique name must not serve stale compiled engines
game.on_technique_change(_clear_compile_caches)


# ---------------------------------------------------------------------------
# runtime inputs + result formatting (the legacy entry points' exact shapes)
# ---------------------------------------------------------------------------

def _day_inputs(env, technique, objective, seed, pretrain, cfg,
                solver_state0=None, routed: bool = False):
    """Replicates the reference loop's key discipline + initial solver state.

    An injected ``solver_state0`` short-circuits state construction (no
    throwaway pretrain/init work) while keeping the key discipline intact.
    """
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    if solver_state0 is not None:
        return key, solver_state0
    t = game.get_technique(technique)
    return key, t.init_state(kp, env, objective, cfg, routed, pretrain)


def _totals_keys(present) -> Tuple[str, ...]:
    """The result's totals keys: the invariant ``_TOTAL_KEYS`` plus any
    degradation metrics the engine actually emitted (faulted/guarded
    engines only — unfaulted result dicts are unchanged)."""
    return _TOTAL_KEYS + tuple(k for k in _FAULT_KEYS if k in present)


def _format_day(ms, hours: int, technique: str, objective: str) -> Dict[str, Any]:
    """Stacked (hours,) metric arrays -> the run_day result dict."""
    host = {k: np.asarray(v).astype(float).tolist() for k, v in ms.items()}
    per_epoch = [{**{k: host[k][t] for k in host}, "tau": t} for t in range(hours)]
    totals = {k: 0.0 for k in _totals_keys(host)}
    for row in per_epoch:
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals, "technique": technique,
            "objective": objective}


# ---------------------------------------------------------------------------
# the façade
# ---------------------------------------------------------------------------

def _trace_stacked(faults) -> bool:
    """Does this FaultTrace carry a leading env-batch axis (one realized
    trace per env row)? Detected off ``avail_mult``: (n, D, 24) vs (D, 24)."""
    return faults is not None and np.ndim(faults.avail_mult) == 3


def run(
    spec: ExperimentSpec,
    envs,
    *,
    peak_state0: Optional[jnp.ndarray] = None,
    solver_state0: Any = None,
    solver: Optional[Callable] = None,
    shard: bool = False,
    record: Any = None,
    faults: Any = None,
) -> Dict[str, Any]:
    """Run one experiment. ``envs`` is a single EnvParams for the scan/loop
    engines, one-or-many (list or stacked) for batched, and one/list/stacked
    per-day rows for month.

    ``solver_state0`` injects an initial solver carry (deployed GT-DRL
    agents); ``solver`` injects a prebuilt stateful closure (loop engine
    only); ``shard=True`` (batched only) shards the env axis across devices
    via ``shard_map`` — identical results, the batch is padded to the device
    count and the padded rows' metrics dropped.

    ``faults`` (a ``repro.faults.FaultTrace``) switches the engine to the
    plan/execute split: solvers plan on the unfaulted ``envs`` while every
    hour executes against the trace's realized env view under
    ``spec.failover``, adding ``unserved_demand`` / ``failover_moved`` /
    ``degraded_sla_cost_usd`` / ``fallback_hours`` to the metrics. The
    batched engine takes either one trace shared across all env rows (the
    same day of trouble hits every scenario) or a stacked per-row trace
    (``faults.stack_traces`` — leading axis matches the env batch, so each
    grid point realizes its own day of trouble). ``faults=None`` (default)
    dispatches the exact unfaulted artifacts.

    ``record`` (True, or a JSONL path) appends a spec-keyed ``RunRecord``
    — totals, convergence curves, engine timing spans, git/jax provenance —
    under ``runs/`` (see ``repro.obs.records``).
    """
    if shard and spec.engine != "batched":
        raise ValueError("shard=True needs engine='batched', "
                         f"got {spec.engine!r}")
    if shard and spec.effective_taps():
        raise ValueError("taps stream through jax.debug.callback, which the "
                         "shard_map engine does not support; run shard=False "
                         "when tapping")
    if solver is not None and spec.engine != "loop":
        raise ValueError("a prebuilt solver closure needs engine='loop', "
                         f"got {spec.engine!r}")
    if peak_state0 is not None and spec.engine == "batched":
        raise ValueError("the batched engine starts every scenario-day from "
                         "a zero peak; peak_state0 is not supported")
    if solver_state0 is not None and spec.engine == "loop":
        raise ValueError("the loop engine derives solver state from the "
                         "seed or a prebuilt solver=; solver_state0 is "
                         "scan/batched/month-only")
    if faults is not None and spec.engine == "month":
        raise ValueError("the month engine does not take realized faults "
                         "yet (a FaultTrace describes one day); run faulted "
                         "days through scan/loop/batched")
    if _trace_stacked(faults) and spec.engine != "batched":
        raise ValueError("a stacked (per-point) FaultTrace needs "
                         f"engine='batched', got {spec.engine!r}; the "
                         "scan/loop engines evaluate one env against one "
                         "trace")
    game.get_technique(spec.technique)  # fail fast with the known-names list
    if spec.engine == "scan":
        result = _run_scan(spec, envs, peak_state0, solver_state0, faults)
    elif spec.engine == "loop":
        result = _run_loop(spec, envs, peak_state0, solver, faults)
    elif spec.engine == "batched":
        result = _run_batched(spec, envs, solver_state0, shard, faults)
    else:
        result = _run_month(spec, envs, peak_state0, solver_state0)
    if record:
        _record_run(spec, result, shard=shard, path=record,
                    faulted=faults is not None,
                    fault_axis=_trace_stacked(faults))
    return result


def _record_run(spec: ExperimentSpec, result: Dict[str, Any], *,
                shard: bool = False, path: Any = None,
                kind: str = "run", faulted: bool = False,
                fault_axis: bool = False) -> str:
    """Emit one JSONL RunRecord for a finished ``run`` result."""
    engine_spans = (None if spec.engine == "loop"
                    else obs.engine_stat(_engine_key(spec, shard=shard,
                                                     faulted=faulted,
                                                     fault_axis=fault_axis)))
    rec = obs.make_record(spec, result, kind=kind, engine_spans=engine_spans)
    return obs.write_record(rec, path if isinstance(path, str) else None)


def _run_scan(spec, env, peak_state0, solver_state0, faults=None):
    key, state0 = _day_inputs(env, spec.technique, spec.objective, spec.seed,
                              spec.pretrain, spec.cfg, solver_state0,
                              spec.routed)
    peak0 = (peak_state0 if peak_state0 is not None
             else jnp.zeros((E.num_dcs(env),)))
    day = _compiled(*_engine_key(spec, faulted=faults is not None))
    if faults is None:
        _, _, ms = day(env, key, peak0, state0)
    else:
        _, _, ms = day(env, key, peak0, state0, faults)
    return _format_day(ms, spec.hours, spec.technique, spec.objective)


def _run_loop(spec, env, peak_state0, solver, faults=None):
    """The seed Python hour-loop, kept as the parity reference (including
    for the faulted plan/execute split — the same ``faults`` helpers run
    eagerly here). Metrics accumulate on-device and transfer with ONE
    ``jax.device_get``."""
    key = jax.random.PRNGKey(spec.seed)
    _, key = jax.random.split(key)
    if solver is None:
        if game.get_technique(spec.technique).stateful:
            # the scan engine's exact init discipline (same kp, same
            # pretrain flag), so loop-vs-scan parity holds for ANY
            # registered stateful technique, not just gt-drl
            _, state0 = _day_inputs(env, spec.technique, spec.objective,
                                    spec.seed, spec.pretrain, spec.cfg,
                                    None, spec.routed)
            solver = SCH.StatefulScheduler(spec.technique, state0,
                                           spec.cfg).solve_epoch
        else:
            solver = SCH.get_scheduler(
                spec.technique, env, spec.objective, routed=spec.routed,
                **({"cfg": spec.cfg} if spec.cfg is not None else {}),
            )
    d = E.num_dcs(env)
    guard_on = spec.guard or faults is not None
    peak = peak_state0 if peak_state0 is not None else jnp.zeros((d,))
    epoch_metrics: List[Dict[str, jnp.ndarray]] = []
    for tau in range(spec.hours):
        key, ks = jax.random.split(key)
        ctx = GameContext(env=env, tau=jnp.int32(tau), objective=spec.objective,
                          routed=spec.routed)
        res = solver(ks, ctx, peak)
        fr = res.fractions
        if guard_on:
            fr, fell_back = FL.guard_fractions(env, jnp.int32(tau), fr)
        ar = fractions_to_ar(ctx, fr)
        if faults is None:
            peak, m = E.step_epoch(env, peak, ar, jnp.int32(tau))
        else:
            peak, m = FL.execute_hour(env, faults, peak, ar, jnp.int32(tau),
                                      spec.failover)
        if guard_on:
            m = {**m, "fallback_hours": fell_back}
        epoch_metrics.append(m)  # stays on device; no per-epoch host sync
    per_epoch: List[Dict[str, float]] = []
    totals = {k: 0.0
              for k in _totals_keys(epoch_metrics[0] if epoch_metrics else ())}
    for tau, m in enumerate(jax.device_get(epoch_metrics)):  # ONE transfer
        row = {k: float(v) for k, v in m.items()}
        row["tau"] = tau
        per_epoch.append(row)
        for k in totals:
            totals[k] += row[k]
    return {"per_epoch": per_epoch, "totals": totals,
            "technique": spec.technique, "objective": spec.objective}


def _run_batched(spec, envs, solver_state0, shard, faults=None):
    if isinstance(envs, E.EnvParams) and envs.er.ndim == 2:
        envs = [envs]  # single env == batch of one (compare_techniques parity)
    if isinstance(envs, E.EnvParams):
        env_b, n = envs, int(envs.er.shape[0])
        env0 = jax.tree_util.tree_map(lambda x: x[0], envs)
    else:
        envs = list(envs)
        env_b, n = E.stack_envs(envs), len(envs)
        env0 = envs[0]
    seeds = list(range(n)) if spec.seeds is None else list(spec.seeds)
    if len(seeds) != n:
        raise ValueError(f"{len(seeds)} seeds for {n} scenario-days")

    # per-day keys split exactly as run_day splits them; gt-drl pretrains
    # ONCE on the first seed's pretrain key (deploy-once semantics)
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(s))[1] for s in seeds])
    _, state0 = _day_inputs(env0, spec.technique, spec.objective, seeds[0],
                            spec.pretrain, spec.cfg, solver_state0, spec.routed)
    peak0 = jnp.zeros((E.num_dcs(env0),))

    faulted = faults is not None
    stacked = _trace_stacked(faults)  # per-row traces vs one shared trace
    if stacked and int(faults.avail_mult.shape[0]) != n:
        raise ValueError(
            f"stacked FaultTrace has {int(faults.avail_mult.shape[0])} rows "
            f"for {n} scenario-days")
    trace = (faults,) if faulted else ()
    if not shard:
        batch = _compiled(*_engine_key(spec, faulted=faulted,
                                       fault_axis=stacked))
        _, _, ms = batch(env_b, keys, peak0, state0, *trace)
    else:
        pad = (-n) % jax.device_count()
        if pad:
            env_b = E.pad_env_batch(env_b, n + pad)
            keys = jnp.concatenate(
                [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])])
            if stacked:  # pad the trace rows alongside their envs
                trace = (jax.tree_util.tree_map(
                    lambda x: jnp.concatenate(
                        [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])]),
                    faults),)
        batch = _compiled(*_engine_key(spec, shard=True, faulted=faulted,
                                       fault_axis=stacked))
        _, _, ms = batch(env_b, keys, peak0, state0, *trace)
        if pad:
            ms = {k: v[:n] for k, v in ms.items()}
    out = {k: np.asarray(v) for k, v in ms.items()}  # (n, hours) each
    totals = {k: out[k].sum(axis=1) for k in _totals_keys(out)}
    return {"totals": totals, "per_epoch": out, "technique": spec.technique,
            "objective": spec.objective, "seeds": seeds}


def _run_month(spec, envs, peak_state0, solver_state0):
    days = spec.days
    if isinstance(envs, E.EnvParams) and envs.er.ndim == 2:
        n = 30 if days is None else int(days)
        env0, env_days = envs, E.tile_env(envs, n)
    elif isinstance(envs, E.EnvParams):
        n = int(envs.er.shape[0])
        env0 = jax.tree_util.tree_map(lambda x: x[0], envs)
        env_days = envs
    else:
        envs = [e if isinstance(e, E.EnvParams) else e[1] for e in envs]
        n, env0, env_days = len(envs), envs[0], E.stack_envs(envs)
    if days is not None and int(days) != n:
        raise ValueError(f"days={days} but {n} per-day envs were given")

    keys = jnp.stack(
        [jax.random.split(jax.random.PRNGKey(spec.seed + d))[1]
         for d in range(n)])
    _, state0 = _day_inputs(env0, spec.technique, spec.objective, spec.seed,
                            spec.pretrain, spec.cfg, solver_state0, spec.routed)
    peak0 = (peak_state0 if peak_state0 is not None
             else jnp.zeros((E.num_dcs(env0),)))

    month = _compiled(*_engine_key(spec))
    final_peak, _, ms, peaks = month(env_days, keys, peak0, state0)
    per_day = {k: np.asarray(v) for k, v in ms.items()}  # (n, hours) each
    day_totals = {k: per_day[k].sum(axis=1) for k in _TOTAL_KEYS}
    return {"per_day": per_day, "day_totals": day_totals,
            "totals": {k: float(day_totals[k].sum()) for k in _TOTAL_KEYS},
            "peak_w": np.asarray(peaks), "final_peak_w": np.asarray(final_peak),
            "days": n, "technique": spec.technique,
            "objective": spec.objective}


# ---------------------------------------------------------------------------
# severity sweeps: parameter grids -> stacked envs -> per-point curves
# ---------------------------------------------------------------------------

def sweep(
    spec: ExperimentSpec,
    grid: Mapping[str, Sequence[Any]],
    *,
    base_env: Optional[E.EnvParams] = None,
    techniques: Optional[Sequence[str]] = None,
    base_scenarios: Sequence[Any] = (),
    cfg_overrides: Optional[Mapping[str, Any]] = None,
    shard: bool = False,
    record: Any = None,
    faults: Any = None,
    resume_dir: Optional[str] = None,
    chunk_points: Optional[int] = None,
    max_retries: int = 2,
    backoff_s: float = 0.25,
    point_timeout_s: Optional[float] = None,
) -> Dict[str, Any]:
    """Severity sweep: the cartesian ``grid`` of scenario-transform
    parameters expands into one stacked env batch, and every technique runs
    through ONE batched compile over all grid points.

    ``grid`` maps a registered transform name to a sequence of points — a
    params dict, or a bare scalar for the transform's declared severity knob
    (``{"wan_degradation": (1.0, 2.0, 4.0), "origin_shift": (0.0, 0.8)}`` is
    a 3 × 2 factor × weight grid). ``base_scenarios`` (Scenario specs or
    transforms) apply to ``base_env`` before every grid point — e.g. an
    ``sla_tighten`` row so misses are priced. Every point runs with
    ``spec.seed``'s RNG stream, so severity is the only variable along a
    curve. ``cfg_overrides`` maps technique -> solver config; ``spec.cfg``
    covers ``spec.technique`` itself, other techniques default. ``faults``
    executes every grid point through the realized plan/execute split under
    ``spec.failover`` — one ``repro.faults.FaultTrace`` shared by every
    point, a sequence of traces (one per grid point, stacked via
    ``faults.stack_traces``), or an already-stacked trace whose leading
    axis matches the grid.

    ``resume_dir`` switches to resumable execution: the grid runs in chunks
    of ``chunk_points`` grid points (default 1) per technique, each
    completed chunk journaled atomically under ``resume_dir`` (see
    ``repro.faults.SweepJournal``). A sweep killed mid-grid re-runs with
    the same arguments and recomputes ONLY the missing chunks; a chunk that
    raises is retried up to ``max_retries`` times with exponential backoff
    (``backoff_s * 2**k``); ``point_timeout_s`` bounds each chunk's wall
    time (a timed-out chunk fails into the retry path). The result gains a
    ``"resume"`` meta block (journal dir, chunks restored vs computed,
    retries, straggler chunks).

    Returns ``{"points": [{name: params}], "labels": [...], "results":
    {technique: {"totals": {k: (P,)}, "per_epoch": {k: (P, hours)}}}}`` —
    each metric row is one grid point's curve (the routed-vs-source-blind
    degradation plot is two techniques of one sweep).
    """
    from .. import scenarios as S

    base_env = base_env if base_env is not None else E.build_env(4, seed=0)
    points, rows = S.build_grid(base_env, grid, base=base_scenarios)
    labels = [lbl for lbl, _ in rows]
    envs = [env for _, env in rows]
    n = len(rows)
    techniques = tuple(techniques) if techniques else (spec.technique,)
    overrides = dict(cfg_overrides or {})

    if faults is not None and not isinstance(faults, FL.FaultTrace):
        faults = FL.stack_traces(faults)  # sequence: one trace per point
    if _trace_stacked(faults) and int(faults.avail_mult.shape[0]) != n:
        raise ValueError(
            f"per-point faults: {int(faults.avail_mult.shape[0])} traces "
            f"for {n} grid points")

    def point_spec(t, n_pts):
        cfg = overrides.get(t, spec.cfg if t == spec.technique else None)
        return spec.replace(technique=t, cfg=cfg, engine="batched",
                            seeds=(spec.seed,) * n_pts)

    if resume_dir is not None:
        results, resume_meta = _sweep_resumable(
            point_spec, envs, techniques, labels, faults=faults,
            shard=shard, resume_dir=resume_dir,
            chunk_points=chunk_points or 1, max_retries=max_retries,
            backoff_s=backoff_s, point_timeout_s=point_timeout_s)
    else:
        resume_meta = None
        env_b = E.stack_envs(envs)
        results = {}
        for t in techniques:
            pspec = point_spec(t, n)
            res = _run_batched(pspec, env_b, None, shard, faults)
            results[t] = {"totals": res["totals"],
                          "per_epoch": res["per_epoch"]}
    if record:
        for t in techniques:
            # one record per technique: each grid point's daily totals form
            # the "curve" along the sweep's label axis
            pspec = point_spec(t, n)
            rec = obs.make_record(
                pspec, {**results[t], "technique": t,
                        "objective": spec.objective},
                kind="sweep",
                curves={k: np.asarray(v, dtype=float).tolist()
                        for k, v in results[t]["totals"].items()},
                engine_spans=obs.engine_stat(
                    _engine_key(pspec, shard=shard,
                                faulted=faults is not None,
                                fault_axis=_trace_stacked(faults))),
                extra={"labels": labels,
                       "grid": {name: list(pts) for name, pts in grid.items()}})
            obs.write_record(rec, record if isinstance(record, str) else None)
    out = {"grid": {name: list(pts) for name, pts in grid.items()},
           "points": points, "labels": labels, "results": results,
           "objective": spec.objective, "hours": spec.hours,
           "routed": spec.routed, "techniques": list(techniques)}
    if resume_meta is not None:
        out["resume"] = resume_meta
    return out


def _sweep_resumable(point_spec, envs, techniques, labels, *, faults, shard,
                     resume_dir, chunk_points, max_retries, backoff_s,
                     point_timeout_s):
    """The journaled chunk-at-a-time sweep path (see ``sweep``'s docstring).

    Execution plan: techniques in order, each technique's grid points in
    chunks of ``chunk_points``; the global chunk index is the journal step.
    Chunks run strictly in order, so the journal is always a prefix of the
    plan and ``SweepJournal.next_step()`` is the resume frontier. The
    supervisor is ``distributed.fault_tolerance.run_with_retries`` — a
    raising chunk is retried with exponential backoff from the frontier;
    ``HeartbeatMonitor`` turns per-chunk wall times into straggler reports.
    """
    import hashlib
    import time as _time

    from ..distributed import fault_tolerance as FT

    n = len(envs)
    chunks = [(start, min(start + chunk_points, n))
              for start in range(0, n, chunk_points)]
    plan = [(t, start, end) for t in techniques for start, end in chunks]
    sig_spec = point_spec(techniques[0], 1)
    sig = hashlib.sha256(repr((
        tuple(labels), tuple(techniques), chunk_points,
        sig_spec.objective, sig_spec.hours, sig_spec.routed,
        sig_spec.failover, sig_spec.guard, sig_spec.seed,
        sig_spec.workload, faults is not None, _trace_stacked(faults),
    )).encode()).hexdigest()[:16]
    journal = FL.SweepJournal(resume_dir, sig)
    monitor = FT.HeartbeatMonitor(num_workers=len(plan),
                                  window=max(len(plan), 1))

    restored_steps = [s for s in journal.completed_steps() if s < len(plan)]
    computed_steps: List[int] = []
    pending: Dict[int, Dict[str, Any]] = {}

    stacked = _trace_stacked(faults)

    def step_fn(step):
        FL.check_kill_switch()
        t, start, end = plan[step]
        pspec = point_spec(t, end - start)
        env_b = E.stack_envs(envs[start:end])
        chunk_faults = (jax.tree_util.tree_map(lambda x: x[start:end], faults)
                        if stacked else faults)
        t0 = _time.perf_counter()
        res = FL.call_with_timeout(
            lambda: _run_batched(pspec, env_b, None, shard, chunk_faults),
            point_timeout_s, label=f"chunk {step} ({t}[{start}:{end}])")
        monitor.record(step, _time.perf_counter() - t0)
        pending[step] = {"totals": {k: np.asarray(v)
                                    for k, v in res["totals"].items()},
                         "per_epoch": {k: np.asarray(v)
                                       for k, v in res["per_epoch"].items()}}
        computed_steps.append(step)

    def save_fn(step_after):
        step = step_after - 1
        if step in pending:  # journal the chunk that just completed
            t, start, end = plan[step]
            journal.mark(step, pending.pop(step),
                         meta={"technique": t, "start": start, "end": end})

    events = FT.run_with_retries(
        step_fn, total_steps=len(plan), save_every=1, save_fn=save_fn,
        restore_fn=journal.next_step,
        policy=FT.FailurePolicy(max_restarts=max_retries, elastic=False),
        retry_on=(Exception,), backoff_s=backoff_s)

    results: Dict[str, Dict[str, Any]] = {}
    for step, (t, start, end) in enumerate(plan):
        part = journal.load(step)
        node = results.setdefault(t, {"totals": {}, "per_epoch": {}})
        for sect in ("totals", "per_epoch"):
            for k, v in part[sect].items():
                node[sect].setdefault(k, []).append(np.asarray(v))
    for t in results:
        for sect in ("totals", "per_epoch"):
            results[t][sect] = {k: np.concatenate(v)
                                for k, v in results[t][sect].items()}
    meta = {"journal": resume_dir, "signature": sig, "chunks": len(plan),
            "chunk_points": chunk_points, "restored": len(restored_steps),
            "computed": len(computed_steps), "retries": events["restarts"],
            "stragglers": [{"chunk": s.worker, "ratio": float(s.ratio)}
                           for s in monitor.stragglers()]}
    return results, meta
