"""Actor / critic MLPs for the DRL components (pure JAX pytrees)."""
from __future__ import annotations

import math
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def mlp_init(key, sizes: Sequence[int], out_scale: float = 0.01) -> Params:
    p: Params = {}
    ks = jax.random.split(key, len(sizes) - 1)
    for li, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        scale = out_scale if li == len(sizes) - 2 else 1.0
        w = jax.random.normal(ks[li], (a, b), jnp.float32) * scale * math.sqrt(2.0 / a)
        p[f"w{li}"] = w
        p[f"b{li}"] = jnp.zeros((b,), jnp.float32)
    return p


def mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    n = len(p) // 2
    for li in range(n):
        x = x @ p[f"w{li}"] + p[f"b{li}"]
        if li < n - 1:
            x = jnp.tanh(x)
    return x


def actor_init(key, state_dim: int, action_dim: int, hidden=(64, 64)) -> Params:
    k1, _ = jax.random.split(key)
    return {
        "mlp": mlp_init(k1, (state_dim, *hidden, action_dim)),
        "log_std": jnp.full((action_dim,), -0.7, jnp.float32),
    }


def actor_mean(p: Params, state: jnp.ndarray) -> jnp.ndarray:
    return mlp_apply(p["mlp"], state)


def actor_sample(p: Params, state: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gaussian in logit space; fractions = softmax(logits).

    Returns (logits, log_prob). The softmax re-parameterization keeps the
    action on the simplex (paper eq. 21) while PPO's ratio lives in the
    Gaussian's density, which is measure-consistent between old/new.
    """
    mu = actor_mean(p, state)
    std = jnp.exp(jnp.clip(p["log_std"], -4.0, 1.0))
    eps = jax.random.normal(key, mu.shape)
    logits = mu + std * eps
    logp = gaussian_logp(logits, mu, std)
    return logits, logp


def gaussian_logp(x, mu, std):
    z = (x - mu) / std
    return jnp.sum(-0.5 * z * z - jnp.log(std) - 0.5 * math.log(2 * math.pi), axis=-1)


def critic_init(key, state_dim: int, hidden=(64, 64)) -> Params:
    return mlp_init(key, (state_dim, *hidden, 1), out_scale=1.0)


def critic_value(p: Params, state: jnp.ndarray) -> jnp.ndarray:
    return mlp_apply(p, state)[..., 0]
