"""NASH: Best-Reply game-theoretic baseline (comparison technique (c), [17]).

Classic sequential best-response: players take turns locally minimizing
their own objective (projected gradient descent in logit space) with the
others fixed, until a full sweep improves nobody. Converges fast but to
*local* equilibria — the deficiency GT-DRL's exploration addresses
(paper §5.1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .game import GameContext, SolveResult, cloud_objective, player_rewards, uniform_fractions


@dataclasses.dataclass(frozen=True)
class NashConfig:
    sweeps: int = 6
    inner_steps: int = 40
    lr: float = 0.4


def _best_reply(ctx: GameContext, peak_state, fractions, i, cfg: NashConfig):
    """Local projected-gradient best response of player i.

    A player's strategy is its (D,) simplex row — or, in a routed game, its
    (S, D) routing matrix (softmax per source row); the logit-space descent
    is identical either way.
    """

    def obj(logits):
        f = fractions.at[..., i, :].set(jax.nn.softmax(logits, axis=-1))
        return player_rewards(ctx, f, peak_state)[i]

    logits0 = jnp.log(fractions[..., i, :] + 1e-9)

    def step(logits, _):
        g = jax.grad(obj)(logits)
        return logits - cfg.lr * g / (jnp.linalg.norm(g) + 1e-9), None

    logits, _ = jax.lax.scan(step, logits0, None, length=cfg.inner_steps)
    better = obj(logits) < obj(logits0)
    return jnp.where(better, jax.nn.softmax(logits, axis=-1),
                     fractions[..., i, :])


def solve_epoch(key, ctx: GameContext, peak_state: jnp.ndarray,
                cfg: NashConfig = NashConfig()) -> SolveResult:
    del key  # deterministic
    i_n = ctx.num_players()
    f = uniform_fractions(ctx)

    def sweep(f, _):
        def per_player(j, f):
            row = _best_reply(ctx, peak_state, f, j, cfg)
            return f.at[..., j, :].set(row)

        f = jax.lax.fori_loop(0, i_n, per_player, f)
        return f, cloud_objective(ctx, f, peak_state)

    f, vals = jax.lax.scan(sweep, f, None, length=cfg.sweeps)
    return SolveResult(f, {"sweep_values": vals, "best": vals[-1]})
