"""Joint (non-game-theoretic) PPO baseline (comparison technique (e), [33]).

One agent controls the entire cloud: state/action dims are |I|·|D| — the
configuration whose state-space growth the paper's decomposition removes.
Reuses the exact PPO machinery of ``core.ppo`` so the comparison isolates
the game-theoretic decomposition, not implementation details.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .game import GameContext, SolveResult, cloud_objective, uniform_fractions
from .ppo import PPOConfig, agent_init, ppo_improve
from . import networks as nets


@dataclasses.dataclass(frozen=True)
class JointPPOConfig:
    ppo: PPOConfig = PPOConfig(horizon=6, episodes=64, iters=40, update_epochs=4)


def solve_epoch(key, ctx: GameContext, peak_state: jnp.ndarray,
                cfg: JointPPOConfig = JointPPOConfig()) -> SolveResult:
    joint = ctx.joint_shape()  # (I, D), or (S, I, D) for routed games
    sdim = adim = int(np.prod(joint))
    k1, k2 = jax.random.split(key)
    agent = agent_init(k1, sdim, adim, cfg.ppo)

    f0 = uniform_fractions(ctx)
    scale = jnp.abs(cloud_objective(ctx, f0, peak_state)) + 1e-6

    def to_f(logits):
        return jax.nn.softmax(logits.reshape(joint), axis=-1)

    def reward_of(logits):
        return -cloud_objective(ctx, to_f(logits), peak_state) / scale

    def state_of(logits):
        return to_f(logits).reshape(-1)

    def state0_fn(k):
        alpha = f0 * 20.0 + 0.5
        fr = jax.random.dirichlet(
            k, jnp.broadcast_to(alpha, (cfg.ppo.episodes,) + alpha.shape))
        return fr.reshape(cfg.ppo.episodes, -1)

    agent, info = ppo_improve(k2, agent, state0_fn, state_of, reward_of, cfg.ppo)
    # greedy output + a short local refinement of the learned proposal
    logits = nets.actor_mean(agent.actor, f0.reshape(-1))

    def polish(lg, _):
        g = jax.grad(lambda z: -reward_of(z))(lg)
        return lg - 0.4 * g / (jnp.linalg.norm(g) + 1e-9), None

    logits, _ = jax.lax.scan(polish, logits, None, length=30)
    row = to_f(logits)
    v_row = cloud_objective(ctx, row, peak_state)
    v0 = cloud_objective(ctx, f0, peak_state)
    best = jnp.where(v_row < v0, row, f0)
    return SolveResult(best, {"best": jnp.minimum(v_row, v0), "mean_reward": info["mean_reward"]})
