"""The non-cooperative workload-distribution game (paper §4–§5.1).

Players are task types i ∈ I; a strategy is the simplex row of desired
fractions DF_i (eq. 21) which maps to arrival rates AR_i = DF_i · CAR_i;
player i's reward is its own estimated carbon CET_i (eq. 12) or cost CCT_i
(eq. 17) given everyone's strategies. The solution concept is Nash
equilibrium (eqs. 19/20): no player can improve unilaterally.

This module holds the shared machinery every solver uses: the strategy
representation, the per-player objective closure, feasibility projection,
and the Nash-residual diagnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dcsim import env as E


@dataclasses.dataclass(frozen=True)
class GameContext:
    """One epoch's decision problem.

    Registered as a pytree (env + tau dynamic, objective static) so solvers
    jit once per env *shape* and run all 24 epochs without recompiling.
    """
    env: E.EnvParams
    tau: Any  # int or traced scalar
    objective: str = "carbon"  # carbon | cost | cost_sla (E.OBJECTIVES)

    def num_players(self) -> int:
        return E.num_players(self.env)

    def num_dcs(self) -> int:
        return E.num_dcs(self.env)


def _ctx_flatten(ctx: GameContext):
    return (ctx.env, ctx.tau), ctx.objective


def _ctx_unflatten(objective, children):
    env, tau = children
    return GameContext(env=env, tau=tau, objective=objective)


jax.tree_util.register_pytree_node(GameContext, _ctx_flatten, _ctx_unflatten)


def fractions_to_ar(ctx: GameContext, fractions: jnp.ndarray) -> jnp.ndarray:
    """(I, D) simplex rows -> feasible AR (eqs. 1, 2, 21)."""
    return E.project_feasible(ctx.env, fractions, ctx.tau)


def uniform_fractions(ctx: GameContext) -> jnp.ndarray:
    i, d = ctx.num_players(), ctx.num_dcs()
    return jnp.full((i, d), 1.0 / d)


def capacity_fractions(ctx: GameContext) -> jnp.ndarray:
    """Effective-ER-proportional start (a natural feasible point).

    Uses the hour's ER·avail so scenario outage/curtailment windows get no
    initial mass; reduces to ER-proportional when avail ≡ 1.
    """
    er_t = E.capacity_at(ctx.env, ctx.tau)
    return er_t / jnp.maximum(jnp.sum(er_t, axis=1, keepdims=True), 1e-9)


def player_rewards(
    ctx: GameContext, fractions: jnp.ndarray, peak_state: jnp.ndarray
) -> jnp.ndarray:
    """(I,) per-player objective values (lower better)."""
    ar = fractions_to_ar(ctx, fractions)
    return E.player_reward(ctx.env, ar, ctx.tau, peak_state, ctx.objective)


def cloud_objective(
    ctx: GameContext, fractions: jnp.ndarray, peak_state: jnp.ndarray
) -> jnp.ndarray:
    """Scalar cloud-level objective (eq. 13 or 18)."""
    return jnp.sum(player_rewards(ctx, fractions, peak_state))


def replace_player(fractions: jnp.ndarray, i, row: jnp.ndarray) -> jnp.ndarray:
    return fractions.at[i].set(row)


def player_objective(
    ctx: GameContext, fractions: jnp.ndarray, i, row: jnp.ndarray,
    peak_state: jnp.ndarray,
) -> jnp.ndarray:
    """Player i's reward when it unilaterally plays ``row``."""
    f = replace_player(fractions, i, row)
    return player_rewards(ctx, f, peak_state)[i]


def nash_residual(
    ctx: GameContext,
    fractions: jnp.ndarray,
    peak_state: jnp.ndarray,
    probe_steps: int = 25,
    lr: float = 0.5,
) -> jnp.ndarray:
    """How far from Nash: max relative unilateral improvement any player can
    find with a short projected-gradient probe. 0 at (local) equilibrium."""
    i_n = fractions.shape[0]

    def probe(i):
        base = player_rewards(ctx, fractions, peak_state)[i]

        def obj(logits):
            return player_objective(ctx, fractions, i, jax.nn.softmax(logits), peak_state)

        logits0 = jnp.log(fractions[i] + 1e-9)

        def step(logits, _):
            g = jax.grad(obj)(logits)
            return logits - lr * g / (jnp.linalg.norm(g) + 1e-9), None

        logits, _ = jax.lax.scan(step, logits0, None, length=probe_steps)
        best = obj(logits)
        return jnp.maximum(base - best, 0.0) / (jnp.abs(base) + 1e-9)

    return jnp.max(jax.vmap(probe)(jnp.arange(i_n)))


# ---------------------------------------------------------------------------
# scheduler interface: every technique maps a GameContext to fractions
# ---------------------------------------------------------------------------

class SolveResult(NamedTuple):
    fractions: jnp.ndarray       # (I, D)
    info: Dict[str, jnp.ndarray]


Scheduler = Callable[..., SolveResult]  # (ctx, peak_state, key) -> SolveResult
