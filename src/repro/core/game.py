"""The non-cooperative workload-distribution game (paper §4–§5.1).

Players are task types i ∈ I; a strategy is the simplex row of desired
fractions DF_i (eq. 21) which maps to arrival rates AR_i = DF_i · CAR_i;
player i's reward is its own estimated carbon CET_i (eq. 12) or cost CCT_i
(eq. 17) given everyone's strategies. The solution concept is Nash
equilibrium (eqs. 19/20): no player can improve unilaterally.

Routed games (``GameContext.routed``, beyond-paper): player i's strategy
grows to an (S, D) matrix — one simplex row per source region — and the
joint strategy is the (S, I, D) routing tensor, so the game decides *which
region's* requests go to which DC and the ``cost_sla`` objective prices
each (source, task) path at its own RTT. All machinery here is shape-
polymorphic: the player axis is always ``axis=-2`` and DC simplex rows are
``axis=-1``, so the same solver code drives both games.

This module holds the shared machinery every solver uses: the strategy
representation, the per-player objective closure, feasibility projection,
and the Nash-residual diagnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import obs
from ..dcsim import env as E


@dataclasses.dataclass(frozen=True)
class GameContext:
    """One epoch's decision problem.

    Registered as a pytree (env + tau dynamic, objective/routed static) so
    solvers jit once per env *shape* and run all 24 epochs without
    recompiling. ``routed`` switches the joint-strategy shape from (I, D)
    to the (S, I, D) routing tensor.
    """
    env: E.EnvParams
    tau: Any  # int or traced scalar
    objective: str = "carbon"  # carbon | cost | cost_sla (E.OBJECTIVES)
    routed: bool = False

    def num_players(self) -> int:
        return E.num_players(self.env)

    def num_dcs(self) -> int:
        return E.num_dcs(self.env)

    def num_sources(self) -> int:
        return E.num_sources(self.env)

    def is_routed(self) -> bool:
        """Whether the joint strategy actually carries a source axis.

        The degenerate S = 1 aggregate origin has nothing to route — one
        source owns all demand — so the routed game *is* the unrouted one
        and runs the identical program (this is what makes the S = 1 parity
        guarantee bit-for-bit: XLA fuses (1, D) and (D,) loop bodies
        differently, so shape-polymorphic code alone drifts in the last
        ulps over compiled solver iterations).
        """
        return self.routed and self.num_sources() > 1

    def joint_shape(self) -> Tuple[int, ...]:
        """Shape of the joint strategy: (S, I, D) routed, (I, D) otherwise.

        One player's strategy is this shape minus the player axis (-2);
        ``gt_drl._row_shape`` is the per-agent version of the same rule.
        """
        i, d = self.num_players(), self.num_dcs()
        return (self.num_sources(), i, d) if self.is_routed() else (i, d)


def _ctx_flatten(ctx: GameContext):
    return (ctx.env, ctx.tau), (ctx.objective, ctx.routed)


def _ctx_unflatten(aux, children):
    env, tau = children
    objective, routed = aux
    return GameContext(env=env, tau=tau, objective=objective, routed=routed)


jax.tree_util.register_pytree_node(GameContext, _ctx_flatten, _ctx_unflatten)


def fractions_to_ar(ctx: GameContext, fractions: jnp.ndarray) -> jnp.ndarray:
    """Simplex rows -> feasible AR (eqs. 1, 2, 21): (I, D) -> (I, D), or the
    routed (S, I, D) tensor -> per-path AR3 (S, I, D)."""
    if ctx.is_routed():
        return E.project_feasible_routed(ctx.env, fractions, ctx.tau)
    return E.project_feasible(ctx.env, fractions, ctx.tau)


def uniform_fractions(ctx: GameContext) -> jnp.ndarray:
    return jnp.full(ctx.joint_shape(), 1.0 / ctx.num_dcs())


def capacity_fractions(ctx: GameContext) -> jnp.ndarray:
    """Effective-ER-proportional start (a natural feasible point).

    Uses the hour's ER·avail so scenario outage/curtailment windows get no
    initial mass; reduces to ER-proportional when avail ≡ 1. Routed games
    broadcast the same source-blind split to every source region.
    """
    er_t = E.capacity_at(ctx.env, ctx.tau)
    f = er_t / jnp.maximum(jnp.sum(er_t, axis=1, keepdims=True), 1e-9)
    return jnp.broadcast_to(f, ctx.joint_shape()) if ctx.is_routed() else f


def player_rewards(
    ctx: GameContext, fractions: jnp.ndarray, peak_state: jnp.ndarray
) -> jnp.ndarray:
    """(I,) per-player objective values (lower better)."""
    ar = fractions_to_ar(ctx, fractions)
    return E.player_reward(ctx.env, ar, ctx.tau, peak_state, ctx.objective)


def cloud_objective(
    ctx: GameContext, fractions: jnp.ndarray, peak_state: jnp.ndarray
) -> jnp.ndarray:
    """Scalar cloud-level objective (eq. 13 or 18)."""
    return jnp.sum(player_rewards(ctx, fractions, peak_state))


def player_row(fractions: jnp.ndarray, i) -> jnp.ndarray:
    """Player i's strategy: (D,) from (I, D), or (S, D) from (S, I, D)."""
    return fractions[..., i, :]


def replace_player(fractions: jnp.ndarray, i, row: jnp.ndarray) -> jnp.ndarray:
    return fractions.at[..., i, :].set(row)


def player_objective(
    ctx: GameContext, fractions: jnp.ndarray, i, row: jnp.ndarray,
    peak_state: jnp.ndarray,
) -> jnp.ndarray:
    """Player i's reward when it unilaterally plays ``row``."""
    f = replace_player(fractions, i, row)
    return player_rewards(ctx, f, peak_state)[i]


def nash_residual(
    ctx: GameContext,
    fractions: jnp.ndarray,
    peak_state: jnp.ndarray,
    probe_steps: int = 25,
    lr: float = 0.5,
) -> jnp.ndarray:
    """How far from Nash: max relative unilateral improvement any player can
    find with a short projected-gradient probe. 0 at (local) equilibrium."""
    i_n = fractions.shape[-2]

    def probe(i):
        base = player_rewards(ctx, fractions, peak_state)[i]

        def obj(logits):
            return player_objective(ctx, fractions, i,
                                    jax.nn.softmax(logits, axis=-1), peak_state)

        logits0 = jnp.log(player_row(fractions, i) + 1e-9)

        def step(logits, _):
            g = jax.grad(obj)(logits)
            return logits - lr * g / (jnp.linalg.norm(g) + 1e-9), None

        logits, _ = jax.lax.scan(step, logits0, None, length=probe_steps)
        best = obj(logits)
        return jnp.maximum(base - best, 0.0) / (jnp.abs(base) + 1e-9)

    return jnp.max(jax.vmap(probe)(jnp.arange(i_n)))


def tap_nash_residual(
    ctx: GameContext,
    fractions: jnp.ndarray,
    peak_state: jnp.ndarray,
    probe_steps: int = 8,
    lr: float = 0.5,
) -> None:
    """Telemetry hook: stream the Nash-residual diagnostic per epoch.

    A no-op unless the ``"game/nash_residual"`` tap is live (see
    ``repro.obs``) — the probe is |I| short gradient ascents, so it is only
    *computed* inside the tapped engine artifact; the taps-off program
    never contains it. ``probe_steps`` defaults lower than the offline
    diagnostic: a per-epoch convergence signal, not a certificate.
    """
    obs.tap("game/nash_residual",
            thunk=lambda: {
                "tau": ctx.tau,
                "residual": nash_residual(ctx, fractions, peak_state,
                                          probe_steps=probe_steps, lr=lr)})


# ---------------------------------------------------------------------------
# scheduler interface: every technique maps a GameContext to fractions
# ---------------------------------------------------------------------------

class SolveResult(NamedTuple):
    fractions: jnp.ndarray       # (I, D), or (S, I, D) for routed games
    info: Dict[str, jnp.ndarray]


Scheduler = Callable[..., SolveResult]  # (ctx, peak_state, key) -> SolveResult


# ---------------------------------------------------------------------------
# technique registry: the ONE name -> solver lookup every engine shares
# ---------------------------------------------------------------------------

def _stateless_init(key, env, objective, cfg, routed: bool, pretrain: bool):
    """Solver state for a stateless technique: the empty carry."""
    return ()


class TechniqueDef(NamedTuple):
    """One registered technique, in the engines' common shape.

    ``step(key, state, ctx, peak_state, cfg) -> (state, SolveResult)`` is
    what the compiled engines scan (``state`` threads the carry — per-player
    agents for gt-drl, ``()`` for stateless solvers);
    ``init_state(key, env, objective, cfg, routed, pretrain)`` builds the
    initial carry (the deploy-once snapshot for stateful techniques).
    """
    name: str
    step: Callable[..., Tuple[Any, SolveResult]]
    default_cfg: Any = None
    init_state: Callable[..., Any] = _stateless_init
    stateful: bool = False

    def resolve_cfg(self, cfg: Any) -> Any:
        """``cfg`` if given, else the registered default (the one rule every
        registry consumer applies)."""
        return cfg if cfg is not None else self.default_cfg


_TECHNIQUES: Dict[str, TechniqueDef] = {}
_REGISTRY_WATCHERS = []  # compile-cache clearers, run when a name is rebound


def on_technique_change(fn: Callable[[], None]) -> None:
    """Register a cache-clear hook run whenever a technique is re-registered
    (``overwrite=True``): compiled engines keyed by technique *name* would
    otherwise serve the stale solver."""
    _REGISTRY_WATCHERS.append(fn)


def register_technique(
    name: str,
    solve_epoch: Optional[Callable] = None,
    *,
    step: Optional[Callable] = None,
    default_cfg: Any = None,
    init_state: Optional[Callable] = None,
    stateful: bool = False,
    overwrite: bool = False,
) -> TechniqueDef:
    """Register a technique so every engine (and ``ExperimentSpec``) can
    drive it by name — external solvers plug in without editing
    ``schedulers.py``.

    Pass exactly one of:

    - ``solve_epoch(key, ctx, peak_state, cfg=...) -> SolveResult`` for a
      stateless solver (the five paper baselines' shape), or
    - ``step(key, state, ctx, peak_state, cfg) -> (state, SolveResult)`` for
      a stateful one (gt-drl's shape) — with ``init_state`` building the
      initial carry and ``stateful=True`` so ``compare_techniques`` deploys
      one snapshot per technique (deploy-once protocol).
    """
    if (solve_epoch is None) == (step is None):
        raise ValueError("pass exactly one of solve_epoch= or step=")
    if solve_epoch is not None:
        fn = solve_epoch

        def step(key, state, ctx, peak_state, cfg):
            return state, fn(key, ctx, peak_state, cfg=cfg)
    if name in _TECHNIQUES:
        if not overwrite:
            raise KeyError(f"technique {name!r} already registered "
                           "(overwrite=True rebinds and clears compile caches)")
        for clear in _REGISTRY_WATCHERS:
            clear()
    t = TechniqueDef(name, step, default_cfg, init_state or _stateless_init,
                     stateful)
    _TECHNIQUES[name] = t
    return t


def unregister_technique(name: str) -> None:
    """Remove a registered technique and clear the compiled-engine caches
    (they are keyed by name — a later registration under the same name must
    not serve the old solver's compiled program)."""
    if _TECHNIQUES.pop(name, None) is not None:
        for clear in _REGISTRY_WATCHERS:
            clear()


def get_technique(name: str) -> TechniqueDef:
    try:
        return _TECHNIQUES[name]
    except KeyError:
        raise KeyError(
            f"unknown technique {name!r}; known: {technique_names()}") from None


def technique_names() -> Tuple[str, ...]:
    return tuple(_TECHNIQUES)
