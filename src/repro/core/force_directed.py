"""FD: Force-Directed greedy baseline (comparison technique (a), [18]).

Adaptation of force-directed scheduling: the "force" on a (task-type, DC)
cell is the marginal objective increase of routing load there; each
iteration greedily moves a quantum of every player's load from its
highest-force DC to its lowest-force DC. Fast, but the myopic quantum moves
stall in local minima (paper §7.1 observes FD over-provisioning nodes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .game import GameContext, SolveResult, cloud_objective, uniform_fractions


@dataclasses.dataclass(frozen=True)
class FDConfig:
    iters: int = 120
    quantum: float = 0.06  # fraction of a player's load moved per iteration


def solve_epoch(key, ctx: GameContext, peak_state: jnp.ndarray,
                cfg: FDConfig = FDConfig()) -> SolveResult:
    del key
    f0 = uniform_fractions(ctx)

    def obj(f):
        return cloud_objective(ctx, f, peak_state)

    def it(carry, _):
        f, best_f, best_v = carry
        # forces: marginal d(objective)/d(fraction) per cell; axis -1 is the
        # DC simplex for both the (I, D) game and the routed (S, I, D) one
        force = jax.grad(obj)(f)
        src = jnp.argmax(jnp.where(f > 1e-6, force, -jnp.inf), axis=-1)
        dst = jnp.argmin(force, axis=-1)
        move = cfg.quantum * jnp.take_along_axis(f, src[..., None], axis=-1)[..., 0]
        onehot_src = jax.nn.one_hot(src, f.shape[-1])
        onehot_dst = jax.nn.one_hot(dst, f.shape[-1])
        f = f - move[..., None] * onehot_src + move[..., None] * onehot_dst
        f = jnp.clip(f, 0.0, None)
        f = f / jnp.sum(f, axis=-1, keepdims=True)
        v = obj(f)
        better = v < best_v
        best_f = jnp.where(better, f, best_f)
        best_v = jnp.where(better, v, best_v)
        return (f, best_f, best_v), v

    v0 = obj(f0)
    (_, best_f, best_v), vals = jax.lax.scan(it, (f0, f0, v0), None, length=cfg.iters)
    return SolveResult(best_f, {"values": vals, "best": best_v})
