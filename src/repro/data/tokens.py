"""Deterministic synthetic token pipeline with O(1) skip-ahead.

Every batch is a pure function of (seed, step): restart from a checkpoint
at step N reproduces batch N+1 bitwise without replaying the stream — the
property the fault-tolerance tests assert. The generator produces Zipf-ish
token ids (so losses are non-degenerate) plus the stub modality inputs each
architecture family needs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def _zipf_tokens(key, shape, vocab: int) -> jnp.ndarray:
    """Zipf-like marginal via exponentiating a uniform (cheap, jittable)."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # inverse-CDF of a truncated power law, exponent ~1.1
    r = jnp.power(u, 3.0)  # skew towards small ids
    ids = jnp.clip((r * vocab).astype(jnp.int32), 0, vocab - 1)
    return ids


def make_batch(cfg: ModelConfig, seed: int, step: int, batch: int, seq: int,
               with_labels: bool = True) -> Dict[str, Any]:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    ks = jax.random.split(key, 4)
    tokens = _zipf_tokens(ks[0], (batch, seq), cfg.vocab_size)
    out: Dict[str, Any] = {"tokens": tokens}
    if with_labels:
        # next-token prediction: labels are the stream shifted left
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        out["labels"] = labels
    if cfg.is_encoder_decoder:
        out["frames"] = jax.random.normal(ks[1], (batch, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :, None], (batch, seq, 3))
        out["positions"] = pos
    if cfg.frontend == "vision_stub":
        sv = min(1024, seq)
        out["vision_embeds"] = jax.random.normal(ks[2], (batch, sv, cfg.d_model), jnp.float32) * 0.02
    return out


class TokenPipeline:
    """Stateful wrapper: checkpointable as a single int (the step cursor)."""

    def __init__(self, cfg: ModelConfig, seed: int, batch: int, seq: int):
        self.cfg, self.seed, self.batch, self.seq = cfg, seed, batch, seq
        self.step = 0

    def next(self) -> Dict[str, Any]:
        b = make_batch(self.cfg, self.seed, self.step, self.batch, self.seq)
        self.step += 1
        return b

    def state(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: Dict[str, int]):
        assert state["seed"] == self.seed, "pipeline seed mismatch"
        self.step = int(state["step"])
