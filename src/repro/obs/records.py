"""Spec-keyed run records: every evaluation can leave a JSONL provenance row.

A ``RunRecord`` is one JSON object per line under ``runs/`` holding
everything needed to attribute and regenerate a result: the spec's fields
and static-key hash, git SHA, jax/device info, the totals, per-epoch
convergence curves, and the engine's compile/dispatch spans from
``obs.cache_stats()``. ``run(spec, envs, record=True)``,
``sweep(..., record=...)`` and ``compare_techniques(..., record=...)`` all
emit through here, so "our strategy outperforms" becomes a committed,
regenerable artifact (``repro.obs.report`` renders a scoreboard from these
files) instead of an ad-hoc example-script printout.

This module is provenance only — it never imports ``repro.core``; specs
arrive duck-typed (any frozen dataclass with the ExperimentSpec fields).
"""
from __future__ import annotations

import dataclasses
import glob as _glob
import hashlib
import json
import os
import subprocess
import time
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

DEFAULT_PATH = os.path.join("runs", "records.jsonl")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_info() -> Dict[str, Any]:
    """Machine/provenance fields stamped on every record (and on
    ``BENCH_*.json`` meta blocks): git SHA, jax version, device kind and
    count, backend, cpu count."""
    import jax
    dev = jax.devices()[0]
    return {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
    }


def _jsonable(x):
    if isinstance(x, (np.ndarray, np.generic)):
        return np.asarray(x).tolist()
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return repr(x)
    return x


def spec_fields(spec) -> Dict[str, Any]:
    """The spec as plain JSON (solver cfg collapses to its repr)."""
    d = dataclasses.asdict(spec)
    if d.get("cfg") is not None:
        d["cfg"] = repr(spec.cfg)
    return _jsonable(d)


def spec_key(spec) -> str:
    """Stable short hash of the spec's compile-relevant (static) fields —
    the join key between records, cache stats and compiled artifacts."""
    return hashlib.sha1(repr(spec.static_key()).encode()).hexdigest()[:12]


def curves_from_result(result: Dict[str, Any],
                       keys: Iterable[str] = ("carbon_kg", "cost_usd",
                                              "sla_miss_cost_usd",
                                              "latency_ms")) -> Dict[str, list]:
    """Per-epoch convergence curves out of any engine's result shape:
    scan/loop's list-of-dicts, batched's (n, hours) arrays (mean over the
    env axis), or month's per-day arrays."""
    per_epoch = result.get("per_epoch", result.get("per_day"))
    curves: Dict[str, list] = {}
    if isinstance(per_epoch, list):  # scan/loop: [{metric: float}, ...]
        for k in keys:
            if per_epoch and k in per_epoch[0]:
                curves[k] = [float(row[k]) for row in per_epoch]
    elif isinstance(per_epoch, dict):  # batched/month: {metric: (n, hours)}
        for k in keys:
            if k in per_epoch:
                curves[k] = np.asarray(per_epoch[k], dtype=float).mean(
                    axis=0).tolist()
    return curves


def make_record(
    spec,
    result: Optional[Dict[str, Any]] = None,
    *,
    kind: str = "run",
    curves: Optional[Dict[str, list]] = None,
    engine_spans: Optional[Dict[str, Any]] = None,
    taps: Optional[Dict[str, int]] = None,
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Assemble one JSONL record from a spec + engine result."""
    rec: Dict[str, Any] = {
        "kind": kind,
        "spec": spec_fields(spec),
        "spec_key": spec_key(spec),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        **run_info(),
    }
    if result is not None:
        rec["totals"] = _jsonable(result.get("totals", {}))
        rec["curves"] = curves if curves is not None else curves_from_result(result)
    elif curves is not None:
        rec["curves"] = curves
    if engine_spans is not None:
        rec["engine_spans"] = _jsonable(engine_spans)
    if taps:
        rec["taps"] = dict(taps)
    if extra:
        rec.update(_jsonable(extra))
    return rec


def write_record(record: Dict[str, Any],
                 path: Optional[str] = None) -> str:
    """Append one record to a JSONL file (default ``runs/records.jsonl``),
    creating the directory as needed. Returns the path written.

    The whole line goes down in ONE ``os.write`` on an ``O_APPEND`` fd:
    concurrent writers (parallel sweeps, a recording run racing the report)
    never interleave bytes, and a crash can at worst truncate the final
    line — which ``load_records`` tolerates — never corrupt earlier ones.
    """
    path = path or DEFAULT_PATH
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    line = json.dumps(record, sort_keys=True) + "\n"
    fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    try:
        os.write(fd, line.encode("utf-8"))
    finally:
        os.close(fd)
    return path


def load_records(*paths: str) -> List[Dict[str, Any]]:
    """Read records back from JSONL files (paths may be globs).

    A truncated TRAILING line (the tail a crash mid-append leaves behind)
    is skipped; a malformed line anywhere else still raises — that is
    corruption, not a torn write, and silently dropping it would bias the
    scoreboard."""
    files: List[str] = []
    for p in paths or (DEFAULT_PATH,):
        hits = sorted(_glob.glob(p))
        files.extend(hits if hits else [p])
    out: List[Dict[str, Any]] = []
    for fp in files:
        with open(fp) as f:
            lines = [ln.strip() for ln in f]
        lines = [ln for ln in lines if ln]
        for i, line in enumerate(lines):
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    continue  # torn final append from a crash: skip it
                raise
    return out
