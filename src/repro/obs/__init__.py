"""``repro.obs`` — telemetry for the compiled evaluation engines.

Three pieces, one invariant: **zero cost when disabled**.

- **Taps** (``repro.obs.tap``): named emission points inside jitted scan
  bodies (``obs.tap(name, value)``). Disabled taps compile to nothing —
  the taps-off engines are bit-for-bit the pre-obs artifacts; enabled taps
  ship per-epoch solver diagnostics and per-hour physical signals to a
  host ring buffer via ``jax.debug.callback``. Built-in tap points:

  ========================  ===================================================
  name                      payload (per event)
  ========================  ===================================================
  ``engine/hour``           tau, carbon_kg, cost_usd, sla_miss_cost_usd,
                            latency_ms, grid_power_w — one event per epoch
  ``game/nash_residual``    tau, residual — the Nash-gap probe (computed
                            only when tapped)
  ``gt_drl/round``          value, best, delta — per best-response round
  ``gt_drl/ppo``            player, actor_loss, mean_reward — per PPO
                            improve call
  ========================  ===================================================

- **Spans** (``repro.obs.spans``): compile-cache accounting for the
  spec-keyed engine cache — hits/misses/evictions, build and
  first-dispatch (≈ compile) wall time, per-dispatch spans — queryable via
  ``obs.cache_stats()``; plus ``obs.span(name)`` for ad-hoc regions (the
  benchmark harness' timer) and ``obs.profile(label)`` for
  ``jax.profiler`` traces.

- **Records** (``repro.obs.records`` / ``repro.obs.report``): ``run(spec,
  envs, record=True)`` (also ``sweep``/``compare_techniques``) appends a
  spec-keyed JSONL ``RunRecord`` (git SHA, jax/device info, totals,
  convergence curves, timing spans) under ``runs/``; ``python -m
  repro.obs`` renders the committed scoreboard from them.

Typical use::

    from repro import obs
    from repro.core import ExperimentSpec, run

    with obs.taps("engine/hour"), obs.capture() as buf:
        run(ExperimentSpec(technique="fd"), env, record=True)
    buf.series("engine/hour", "carbon_kg")   # (24,) convergence curve
    obs.cache_stats()                        # compile/dispatch accounting
"""
from . import records, report as report_mod, spans, tap as tap_mod
from .records import (load_records, make_record, run_info, spec_fields,
                      spec_key, write_record)
from .report import report, sparkline
from .spans import (Span, cache_stats, engine_key_str, engine_stat,
                    note_bench, profile, reset_stats, span)
from .spans import spans as all_spans
from .tap import (KNOWN_TAPS, TapBuffer, TapEvent, active_taps, capture,
                  clear_events, disable_taps, enable_taps, enabled, events,
                  ring, tap, taps, tracing)

__all__ = [
    "tap", "taps", "capture", "events", "ring", "clear_events",
    "enable_taps", "disable_taps", "enabled", "active_taps", "tracing",
    "KNOWN_TAPS", "TapBuffer", "TapEvent",
    "span", "all_spans", "Span", "cache_stats", "engine_stat",
    "engine_key_str", "reset_stats", "note_bench", "profile",
    "make_record", "write_record", "load_records", "run_info",
    "spec_fields", "spec_key",
    "report", "sparkline",
]
