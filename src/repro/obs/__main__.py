"""``python -m repro.obs`` — render the scoreboard from JSONL run records
(the runpy-clean alias for ``repro.obs.report.main``)."""
from .report import main

main()
