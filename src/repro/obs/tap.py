"""In-scan metric streams: ``tap(name, value)`` out of jitted code.

A *tap* is a named emission point inside traced/compiled code (engine scan
bodies, GT-DRL best-response rounds). Whether a tap is live is decided at
**trace time** against the active tap set, so

- a disabled tap compiles to *nothing* — ``tap`` returns before touching
  jax, the lowered program is byte-identical to one with no tap call, and
  the taps-off engines stay pinned bit-for-bit against their pre-obs
  artifacts;
- an enabled tap lowers to a ``jax.debug.callback`` that ships the value
  (any pytree of arrays) to a host-side ring buffer at run time. Callbacks
  do not change the math: XLA treats them as opaque effects, and the
  engine parity tests assert taps-on == taps-off exactly.

Because liveness is a compile-time property, every compiled-engine cache in
``repro.core.experiment`` keys on the active tap set: enabling taps
compiles a *second* artifact instead of mutating the first, and disabling
them again is a cache hit on the original.

Expensive diagnostics (the Nash-residual probe) use the ``thunk=`` form so
the value is only *computed* when the tap is live::

    obs.tap("game/nash_residual", thunk=lambda: nash_residual(...))

Enablement is either ambient (``with obs.taps("engine/*"): ...``) or
per-spec (``ExperimentSpec(taps=("engine/hour",))``); patterns are exact
names, ``prefix/*`` wildcards, or ``"*"`` for everything.
"""
from __future__ import annotations

import collections
import functools
import threading
from contextlib import contextmanager
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import numpy as np

DEFAULT_CAPACITY = 65536

#: The tap registry: every name ever passed to ``tap(...)`` must be
#: declared here, and ``repro.lint``'s taps checker enforces it statically
#: (a typo'd name would otherwise compile to a tap that never fires).
#: Keep sorted; keep literal — the lint pass reads this tuple from the AST.
KNOWN_TAPS = (
    "engine/hour",          # experiment engines: per-hour scan-body metrics
    "game/nash_residual",   # game loop: best-reply residual probe
    "gt_drl/ppo",           # GT-DRL: per-player PPO actor/critic losses
    "gt_drl/round",         # GT-DRL: per-round best-response telemetry
)


class TapEvent(NamedTuple):
    """One host-side record: the tap's name and its value (numpy pytree)."""
    name: str
    value: Any


class TapBuffer:
    """Bounded ring buffer of ``TapEvent``s (oldest events drop first)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self._dq: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()

    def append(self, event: TapEvent) -> None:
        with self._lock:
            self._dq.append(event)

    def __len__(self) -> int:
        return len(self._dq)

    def clear(self) -> None:
        with self._lock:
            self._dq.clear()

    @property
    def events(self) -> List[TapEvent]:
        return list(self._dq)

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted({e.name for e in self._dq}))

    def by_name(self, name: str) -> List[Any]:
        """All values emitted under ``name``, in arrival order."""
        return [e.value for e in self._dq if e.name == name]

    def series(self, name: str, field: Optional[str] = None) -> np.ndarray:
        """Stack a tap's values (or one ``field`` of dict-valued taps) into
        one array — the convergence-curve accessor."""
        vals = self.by_name(name)
        if field is not None:
            vals = [v[field] for v in vals]
        return np.stack([np.asarray(v) for v in vals]) if vals else np.empty((0,))

    def counts(self) -> dict:
        out: dict = {}
        for e in self._dq:
            out[e.name] = out.get(e.name, 0) + 1
        return out


# ---------------------------------------------------------------------------
# module state: the active tap set (trace time) + the sink stack (run time)
# ---------------------------------------------------------------------------

_ACTIVE: frozenset = frozenset()   # patterns live at trace time
_RING = TapBuffer()                # default sink
_SINKS: List[TapBuffer] = [_RING]  # capture() pushes/pops


def active_taps() -> frozenset:
    """The ambient tap patterns — part of every compiled-engine cache key."""
    return _ACTIVE


def normalize(patterns) -> frozenset:
    """None/str/iterable -> the frozenset compile-key form."""
    if patterns is None:
        return frozenset()
    if isinstance(patterns, str):
        patterns = (patterns,)
    return frozenset(patterns)


@functools.lru_cache(maxsize=1024)
def _matches(name: str, patterns: frozenset) -> bool:
    for p in patterns:
        if p == name or p == "*" or (p.endswith("/*") and
                                     name.startswith(p[:-1])):
            return True
    return False


def enabled(name: str) -> bool:
    """Trace-time liveness check for one tap name."""
    return bool(_ACTIVE) and _matches(name, _ACTIVE)  # lint: host-ok(liveness is decided over the host-side active-pattern set at trace time, never over traced values)


def enable_taps(*patterns: str) -> None:
    global _ACTIVE
    _ACTIVE = _ACTIVE | normalize(patterns)


def disable_taps() -> None:
    global _ACTIVE
    _ACTIVE = frozenset()


@contextmanager
def taps(*patterns: str):
    """Ambient enablement: every tap matching ``patterns`` is live for runs
    dispatched inside the block (a different compiled artifact — the
    taps-off one is untouched and stays cached)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = prev | normalize(patterns)
    try:
        yield _RING
    finally:
        _ACTIVE = prev


@contextmanager
def tracing(patterns: frozenset):
    """Pin the active set to exactly ``patterns`` for the duration.

    The compiled engines wrap every dispatch in this so the program traced
    under a cache key always matches that key's tap set — no matter when
    jit decides to trace or what the ambient state is by then.
    """
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = frozenset(patterns)
    try:
        yield
    finally:
        _ACTIVE = prev


@contextmanager
def capture(*patterns: str, capacity: int = DEFAULT_CAPACITY):
    """Collect events into a fresh buffer (and enable ``patterns``, if any).

    ``with obs.capture("engine/hour") as buf: run(...)`` leaves the global
    ring untouched and hands back exactly this block's events.
    """
    buf = TapBuffer(capacity)
    _SINKS.append(buf)
    try:
        if patterns:
            with taps(*patterns):
                yield buf
        else:
            yield buf
    finally:
        _SINKS.remove(buf)


def _record(name: str, value) -> None:
    """The host-side callback target: numpy-ify and append to the live sink."""
    import jax
    host = jax.tree_util.tree_map(np.asarray, value)
    _SINKS[-1].append(TapEvent(name, host))


def tap(name: str, value: Any = None, *, thunk: Optional[Callable] = None):
    """Emit ``value`` (any pytree of arrays) under ``name`` — from inside or
    outside jitted code.

    When ``name`` is not in the active tap set this is a pure no-op: nothing
    is traced, nothing is lowered, the compiled program is unchanged. When
    live, ``thunk`` (if given) is called to *build* the value — use it for
    diagnostics that are expensive to compute — and the value travels to the
    current host sink via ``jax.debug.callback``.
    """
    if not enabled(name):
        return
    import jax
    if thunk is not None:
        value = thunk()
    jax.debug.callback(functools.partial(_record, name), value)  # lint: host-ok(the sanctioned obs escape hatch: an opaque effect that ships values to the host ring; parity tests pin taps-on == taps-off)


def events(name: Optional[str] = None) -> List[TapEvent]:
    """The default ring's events (optionally filtered by exact name)."""
    evs = _RING.events
    return evs if name is None else [e for e in evs if e.name == name]


def ring() -> TapBuffer:
    return _RING


def clear_events() -> None:
    _RING.clear()
