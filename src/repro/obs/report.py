"""Scoreboard generator: JSONL run records -> a committed markdown table.

DCcluster-Opt-style benchmark reporting (PAPERS.md): "our strategy
outperforms" should be a regenerable artifact, not a one-off print. This
module turns ``runs/*.jsonl`` records into a ranked markdown scoreboard
with per-technique totals, convergence sparklines, and the engine's
compile/dispatch spans — one command reproduces the committed
``SCOREBOARD.md``::

    python -m repro.obs runs/records.jsonl -o SCOREBOARD.md
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from . import records as R

_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values: Iterable[float], width: int = 16) -> str:
    """Unicode sparkline of a curve, resampled to ``width`` points."""
    v = np.asarray(list(values), dtype=float)
    if v.size == 0 or not np.all(np.isfinite(v)):
        return ""
    if v.size > width:
        idx = np.linspace(0, v.size - 1, width).round().astype(int)
        v = v[idx]
    lo, hi = float(v.min()), float(v.max())
    if hi - lo < 1e-12:
        return _BLOCKS[0] * v.size
    t = (v - lo) / (hi - lo)
    return "".join(_BLOCKS[int(x * (len(_BLOCKS) - 1))] for x in t)


def _tot(rec: Dict[str, Any], key: str) -> Optional[float]:
    """A scalar total out of any record shape: scan/loop scalars, batched
    per-env arrays (mean), compare records' ``mean``."""
    totals = rec.get("totals", {})
    v = totals.get(key)
    if isinstance(v, list):
        return float(np.mean(v)) if v else None
    if v is None and key == rec.get("metric") and "mean" in rec:
        return float(rec["mean"])
    return None if v is None else float(v)


def _rank_metric(rec: Dict[str, Any]) -> str:
    return "carbon_kg" if rec["spec"].get("objective") == "carbon" else "cost_usd"


def _fmt(v: Optional[float], nd: int = 1) -> str:
    return "—" if v is None else f"{v:.{nd}f}"


def report(recs: List[Dict[str, Any]], title: str = "Scoreboard") -> str:
    """Render records as a markdown scoreboard, ranked per objective group
    by daily carbon (``objective="carbon"``) or total cost otherwise."""
    lines = [f"# {title}", ""]
    if not recs:
        return "\n".join(lines + ["_no records_", ""])
    info = {(r.get("git_sha"), r.get("jax_version"), r.get("device_kind"))
            for r in recs}
    for sha, jaxv, dev in sorted(info, key=str):
        lines.append(f"- git `{sha}` · jax {jaxv} · {dev} "
                     f"({sum(1 for r in recs if r.get('git_sha') == sha)} records)")
    lines.append("")

    by_obj: Dict[str, List[Dict[str, Any]]] = {}
    for r in recs:
        by_obj.setdefault(r["spec"].get("objective", "?"), []).append(r)

    for obj in sorted(by_obj):
        group = by_obj[obj]
        metric = _rank_metric(group[0])
        group = sorted(group, key=lambda r: (_tot(r, metric)
                                             if _tot(r, metric) is not None
                                             else float("inf")))
        lines += [f"## objective = `{obj}` (ranked by `{metric}`, lower is better)",
                  "",
                  "| technique | engine | hours | carbon_kg | cost_usd | "
                  "sla_usd | convergence | dispatch_ms | compile_s | spec key |",
                  "|---|---|---:|---:|---:|---:|---|---:|---:|---|"]
        for r in group:
            spec = r["spec"]
            curves = r.get("curves", {})
            curve = curves.get(metric) or next(iter(curves.values()), [])
            sp = r.get("engine_spans") or {}
            disp = (sp.get("dispatch_s", 0.0) / sp["dispatches"] * 1e3
                    if sp.get("dispatches") else None)
            lines.append(
                "| {t} | {e} | {h} | {c} | {u} | {s} | `{cv}` | {d} | {k} | `{key}` |".format(
                    t=spec.get("technique"), e=spec.get("engine"),
                    h=spec.get("hours"),
                    c=_fmt(_tot(r, "carbon_kg")),
                    u=_fmt(_tot(r, "cost_usd")),
                    s=_fmt(_tot(r, "sla_miss_cost_usd")),
                    cv=sparkline(curve) or "n/a",
                    d=_fmt(disp, 1),
                    k=_fmt(sp.get("first_dispatch_s"), 2),
                    key=r.get("spec_key", "?")))
        lines.append("")
    lines += ["Convergence column: per-epoch curve of the ranked metric "
              "(sparkline, earliest epoch left). `compile_s` is the first-"
              "dispatch span (trace + XLA compile + run); `dispatch_ms` the "
              "mean steady-state dispatch.", ""]
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="render a markdown scoreboard from JSONL run records")
    ap.add_argument("paths", nargs="*", default=[R.DEFAULT_PATH],
                    help="record files (globs ok); default runs/records.jsonl")
    ap.add_argument("-o", "--out", default=None,
                    help="write markdown here (default: stdout)")
    ap.add_argument("--title", default="Scoreboard")
    args = ap.parse_args(argv)
    md = report(R.load_records(*args.paths), title=args.title)
    if args.out:
        # write-then-rename: a reader (or a crash) never sees a half
        # scoreboard
        import os
        tmp = args.out + ".tmp"
        with open(tmp, "w") as f:
            f.write(md)
        os.replace(tmp, args.out)
        print(f"wrote {args.out}")
    else:
        print(md)


if __name__ == "__main__":
    main()
