"""Compile- and dispatch-span accounting for the compiled engines.

Before this module, compile time was silently folded into wall time and the
spec-keyed compile cache in ``repro.core.experiment`` was opaque — a perf
number could mean "fast engine" or "you hit the cache" and nothing could
tell them apart. Three pieces:

- ``span(name)`` — a ``with``-able wall-clock span (``.seconds`` after
  exit). The benchmark harness' ``Timer`` is this span under another name,
  so bench rows and engine telemetry share one timing code path
  (``note_bench`` records the emitted rows here too).
- engine-cache accounting — ``repro.core.experiment._compiled`` reports
  every lookup (``engine_lookup``), wraps every artifact's dispatch
  (``instrument_dispatch``: per-call wall time, first-dispatch time ≈
  trace+XLA-compile+run, and the trace-time tap pinning), and reports
  evictions (``note_eviction``, fired by ``register_technique(overwrite=
  True)`` / ``unregister_technique``). ``cache_stats()`` is the queryable
  view; a test asserts the taps-off path adds zero compiles.
- ``profile(label)`` — optional ``jax.profiler`` trace dropped under
  ``runs/profiles/<label>`` for kernel-level work (the ROADMAP's Pallas
  item); degrades to a no-op warning where the profiler is unavailable.

Dispatch wrappers block on their outputs (``jax.block_until_ready``) so the
recorded span covers the actual computation and every live tap callback has
landed in its buffer before the engine returns — numerics are unaffected.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional

from . import tap as _tap

SPAN_CAPACITY = 4096


@dataclasses.dataclass
class Span:
    """One timed region. ``seconds`` is set when the region exits."""
    name: str
    seconds: float = 0.0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _t0: float = dataclasses.field(default=0.0, repr=False)

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self._t0
        _SPANS.append(self)


_SPANS: collections.deque = collections.deque(maxlen=SPAN_CAPACITY)


def span(name: str, **meta) -> Span:
    """``with obs.span("phase") as s: ...`` — then read ``s.seconds``."""
    return Span(name=name, meta=meta)


def spans(name: Optional[str] = None) -> List[Span]:
    out = list(_SPANS)
    return out if name is None else [s for s in out if s.name == name]


def note_bench(name: str, seconds: float, derived: str = "") -> None:
    """Record one benchmark row as a span (the bench harness' ``emit``
    routes through here, so ``BENCH_*.json`` rows and engine spans are the
    same measurements)."""
    _SPANS.append(Span(name=name, seconds=seconds,
                       meta={"kind": "bench", "derived": derived}))


# ---------------------------------------------------------------------------
# engine compile-cache accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineStat:
    """Per compile-key counters for one cached engine artifact."""
    hits: int = 0
    misses: int = 0
    build_s: float = 0.0           # python-side jit/vmap/shard_map wrap time
    first_dispatch_s: float = 0.0  # ≈ trace + XLA compile + first run
    dispatches: int = 0
    dispatch_s: float = 0.0        # total wall across all dispatches
    last_dispatch_s: float = 0.0
    evicted: bool = False

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["dispatch_s"] = round(d["dispatch_s"], 6)
        for k in ("build_s", "first_dispatch_s", "last_dispatch_s"):
            d[k] = round(d[k], 6)
        return d


_known: set = set()                      # keys with a live cached artifact
_engine: Dict[str, EngineStat] = {}      # resettable accounting, by key string
_evictions: int = 0


def engine_key_str(key: tuple) -> str:
    """Compact, human-scannable form of an engine compile key:
    ``kind:technique:objective:h<hours>:cfg=<...>:routed=<...>:
    wl=<workload>:faults=<policy|off[/point]>:guard=<on|off>:taps=<...>``."""
    (kind, technique, objective, hours, cfg, routed, failover, guard,
     workload, faulted, fault_axis, taps) = key
    cfg_s = "default" if cfg is None else type(cfg).__name__
    taps_s = ",".join(sorted(taps)) if taps else "off"
    faults_s = failover if faulted else "off"
    if faulted and fault_axis:
        faults_s += "/point"  # one trace per env row
    return (f"{kind}:{technique}:{objective}:h{hours}:cfg={cfg_s}:"
            f"routed={bool(routed)}:wl={workload}:faults={faults_s}:"
            f"guard={'on' if guard else 'off'}:taps={taps_s}")


def _stat(key: tuple) -> EngineStat:
    ks = engine_key_str(key)
    st = _engine.get(ks)
    if st is None:
        st = _engine[ks] = EngineStat()
    return st


def engine_lookup(key: tuple) -> bool:
    """Count one compile-cache lookup; returns True on a hit."""
    hit = key in _known
    st = _stat(key)
    if hit:
        st.hits += 1
    else:
        st.misses += 1
        _known.add(key)
    return hit


def note_build(key: tuple, seconds: float) -> None:
    _stat(key).build_s += seconds


def note_eviction() -> None:
    """The compile caches were cleared (technique re-registered/removed):
    every known artifact is gone; the next lookups are misses again."""
    global _evictions
    if _known:
        _evictions += len(_known)
        _known.clear()
    for st in _engine.values():
        st.evicted = True


def instrument_dispatch(key: tuple, fn: Callable) -> Callable:
    """Wrap a compiled engine so every call is a timed span, the first call
    is recorded as the compile span, and tracing happens under exactly the
    key's tap set (see ``tap.tracing``)."""
    import jax
    taps = key[-1]

    def dispatch(*args, **kwargs):
        st = _stat(key)
        t0 = time.perf_counter()
        with _tap.tracing(taps):
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        st.dispatches += 1
        st.dispatch_s += dt
        st.last_dispatch_s = dt
        if st.dispatches == 1:
            st.first_dispatch_s = dt
        return out

    dispatch.__wrapped__ = fn
    return dispatch


def cache_stats() -> Dict[str, Any]:
    """The queryable compile-cache view: global hit/miss/eviction totals
    plus per-engine-key spans (``{"engines": {key: EngineStat dict}}``)."""
    return {
        "hits": sum(s.hits for s in _engine.values()),
        "misses": sum(s.misses for s in _engine.values()),
        "evictions": _evictions,
        "live_keys": len(_known),
        "engines": {k: s.as_dict() for k, s in _engine.items()},
    }


def engine_stat(key: tuple) -> Optional[Dict[str, Any]]:
    st = _engine.get(engine_key_str(key))
    return None if st is None else st.as_dict()


def reset_stats() -> None:
    """Zero the accounting (counters/spans). Does NOT touch the live
    compiled artifacts: keys still cached keep hitting, so post-reset
    numbers stay truthful about what actually compiled."""
    global _evictions
    _engine.clear()
    _SPANS.clear()
    _evictions = 0


# ---------------------------------------------------------------------------
# profiler traces
# ---------------------------------------------------------------------------

@contextmanager
def profile(label: str = "trace", logdir: str = "runs/profiles"):
    """Drop a ``jax.profiler`` trace for the block under
    ``<logdir>/<label>`` (viewable in TensorBoard/Perfetto; the tool for
    the queued Pallas-kernel work). Yields the trace directory, or ``None``
    with a warning where the profiler is unavailable."""
    import os

    import jax
    path = os.path.join(logdir, label)
    try:
        os.makedirs(path, exist_ok=True)
        jax.profiler.start_trace(path)
    except Exception as e:  # pragma: no cover - environment-dependent
        warnings.warn(f"jax profiler unavailable ({e!r}); profile({label!r}) "
                      "is a no-op")
        yield None
        return
    try:
        yield path
    finally:
        jax.profiler.stop_trace()
