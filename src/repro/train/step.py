"""Training and serving step functions (the units the dry-run lowers).

The LM loss is computed *chunked over the sequence*: the (B, S, V) logits
tensor — 318 GB global for qwen2-7b × train_4k — is never materialized;
hidden states are unembedded and soft-maxed 512 tokens at a time inside a
scan. This is a memory-roofline optimization that XLA cannot do on its own.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as model_lib
from ..models.layers import unembed
from ..optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from ..optim.schedules import warmup_cosine

LOSS_CHUNK = 512


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig) -> TrainState:
    params = model_lib.init(key, cfg)
    return TrainState(params, adamw_init(params, opt_cfg))


def _chunked_ce(table, hidden: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Cross entropy without materializing full logits.

    hidden: (B, S, D) final normed states; labels (B, S) with next-token ids
    already aligned by the caller; label -1 masks a position out.
    """
    b, s, d = hidden.shape
    chunk = min(LOSS_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    h_chunks = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    l_chunks = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint  # recompute the (B,C,V) logits in backward: the fp32
    def _chunk_nll(h, lab):  # logits of all chunks must never be live at once
        logits = unembed(table, h).astype(jnp.float32)  # (B, C, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, inp):
        h, lab = inp
        nll, cnt = _chunk_nll(h, lab)
        return (carry[0] + nll, carry[1] + cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (h_chunks, l_chunks))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    labels = batch["labels"]
    if cfg.is_encoder_decoder:
        logits, aux = model_lib.forward(params, cfg, batch)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        hidden, aux = model_lib.hidden_forward(params, cfg, batch)
        table = params["head"] if "head" in params else params["embed"]
        ce = _chunked_ce(table, hidden, labels)
    total = ce + cfg.router_aux_loss * aux
    return total, {"ce": ce, "aux": aux}


def train_step(
    state: TrainState,
    batch: Dict[str, Any],
    *,
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    schedule_kwargs: Optional[dict] = None,
) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    """One optimizer step. Jit with static cfg/opt_cfg and donated state."""
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params, cfg, batch)
    # pin the DP-reduction boundary to the params' dtype: without this XLA
    # fuses the optimizer's f32 upcast into the gradient all-reduce and moves
    # 2x the bytes over the wire (measured on mistral-123b × train_4k)
    grads = jax.tree_util.tree_map(
        lambda g, p: g.astype(p.dtype), grads, state.params)
    lr_scale = warmup_cosine(state.opt.step, **(schedule_kwargs or {}))
    new_params, new_opt, om = adamw_update(grads, state.opt, state.params, opt_cfg, lr_scale)
    metrics = {"loss": loss, **parts, **om, "step": new_opt.step}
    return TrainState(new_params, new_opt), metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, schedule_kwargs=None):
    return functools.partial(
        train_step, cfg=cfg, opt_cfg=opt_cfg, schedule_kwargs=schedule_kwargs
    )


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def prefill_step(params, batch: Dict[str, Any], *, cfg: ModelConfig, cache_len: int):
    """Prompt pass: returns (last-token logits, filled cache)."""
    return model_lib.prefill(params, cfg, batch, cache_len)


def decode_step(params, token, positions, cache, *, cfg: ModelConfig):
    """One new token for every sequence in the batch, cache donated."""
    return model_lib.decode_step(params, cfg, token, positions, cache)


def forward_step(params, batch: Dict[str, Any], *, cfg: ModelConfig):
    """Plain forward (used by evaluation + tests)."""
    return model_lib.forward(params, cfg, batch)
